//! Checkpoint payload codec: a flat, versioned, little-endian encoding of
//! everything a [`StreamSession`](super::StreamSession) needs to come back
//! bit-identical — configuration, counters, the id remap (bounded by its
//! compacted `base`), the admission filter's τ ladder, and the live
//! storage (feature rows plus, for sparse facility location, the top-`t`
//! neighbor lists, which are stream *history* and not reproducible from
//! the surviving rows).
//!
//! This module is pure data + bytes: capture (session → [`CheckpointState`])
//! and restore ([`CheckpointState`] → session) live in `session.rs`, next
//! to the private state they touch; the encoding below never sees a
//! session. Integrity is the WAL layer's job — the payload travels inside
//! a checksummed [`frame_checkpoint`](super::wal::frame_checkpoint) — so
//! decode errors here mean structural corruption and surface as
//! [`WalError::Corrupt`], which recovery maps to a typed quarantine.

use crate::algorithms::sieve_filter::SieveParams;
use crate::algorithms::{Sampling, SsParams};
use crate::submodular::{BuildStrategy, Concave};
use crate::util::vecmath::FeatureMatrix;

use super::wal::{put_f32, put_f64, put_u32, put_u64, put_u8, Cursor, WalError};

/// Payload format version (bump on any layout change).
/// v2: facility stores carry their [`BuildStrategy`] and, when the sparse
/// store was LSH-built, the index geometry `(tables, bits, adapt_floor)`.
const VERSION: u8 = 2;

/// Exported sparse-similarity state (`SparseSimStore::export_parts`).
pub(crate) struct SparseParts {
    pub(crate) n: usize,
    pub(crate) t: usize,
    pub(crate) len: Vec<u32>,
    pub(crate) cols: Vec<u32>,
    pub(crate) vals: Vec<f32>,
    /// LSH index geometry `(tables, bits, adapt_floor)` when the store was
    /// LSH-built (`adapt_floor` 0 = explicit-t build, no adaptive budget).
    /// Only geometry persists: the projections are derived from a fixed
    /// seed, so restore rehashes the rows and gets the identical index.
    pub(crate) lsh: Option<(u32, u32, u32)>,
}

/// Live-storage payload: enough to rebuild the session's `LiveStore`
/// exactly (and its lazily-built objective bit-identically).
pub(crate) enum StorePayload {
    Features {
        concave: Concave,
        rows: FeatureMatrix,
    },
    Facility {
        crossover: usize,
        t: Option<usize>,
        /// Neighbor-build strategy for post-recovery (re)builds — restored
        /// sessions must pick the same exact/LSH path the live one would.
        build: BuildStrategy,
        rows: FeatureMatrix,
        /// The live sparse store, when one was built — post-eviction
        /// neighbor lists must come from here, not a row rebuild.
        sparse: Option<SparseParts>,
    },
}

/// One sieve threshold's durable state.
pub(crate) struct SievePayload {
    pub(crate) tau: f64,
    pub(crate) value: f64,
    pub(crate) len: usize,
    pub(crate) cov: Vec<f32>,
}

/// The admission filter's durable state.
pub(crate) struct FilterPayload {
    pub(crate) max_singleton: f64,
    pub(crate) peak_resident: usize,
    pub(crate) sieves: Vec<SievePayload>,
}

/// The complete durable image of a session at one WAL position: records
/// with `seq < wal_seq` are covered; recovery replays only the tail.
pub(crate) struct CheckpointState {
    pub(crate) wal_seq: u64,
    pub(crate) d: usize,
    // --- StreamConfig ---
    pub(crate) k: usize,
    pub(crate) ss: SsParams,
    pub(crate) high_water: usize,
    pub(crate) max_live: usize,
    pub(crate) admission: Option<SieveParams>,
    pub(crate) shards: usize,
    pub(crate) intermediate_eps: f64,
    pub(crate) reserve_hint: usize,
    // --- lifetime counters / flags ---
    pub(crate) windows: u64,
    pub(crate) ss_rounds: u64,
    pub(crate) appends: u64,
    pub(crate) admitted: u64,
    pub(crate) evicted: u64,
    pub(crate) closed: bool,
    // --- live-set shape ---
    pub(crate) retained_len: usize,
    pub(crate) buffer_len: usize,
    // --- id remap (`IdRemap::export_parts`) ---
    pub(crate) base: usize,
    pub(crate) ext_to_int: Vec<u32>,
    pub(crate) int_to_ext: Vec<usize>,
    // --- admission filter ---
    pub(crate) filter: Option<FilterPayload>,
    // --- storage ---
    pub(crate) store: StorePayload,
}

fn put_usize(out: &mut Vec<u8>, v: usize) {
    put_u64(out, v as u64);
}

fn put_bool(out: &mut Vec<u8>, v: bool) {
    put_u8(out, u8::from(v));
}

fn put_matrix(out: &mut Vec<u8>, m: &FeatureMatrix) {
    put_usize(out, m.n());
    put_usize(out, m.d());
    for &v in m.data() {
        put_f32(out, v);
    }
}

fn corrupt(msg: &str) -> WalError {
    WalError::Corrupt(format!("checkpoint payload: {msg}"))
}

fn get_usize(c: &mut Cursor<'_>) -> Result<usize, WalError> {
    let v = c.u64()?;
    usize::try_from(v).map_err(|_| corrupt("length field overflows usize"))
}

fn get_bool(c: &mut Cursor<'_>) -> Result<bool, WalError> {
    match c.u8()? {
        0 => Ok(false),
        1 => Ok(true),
        other => Err(corrupt(&format!("bad bool byte {other}"))),
    }
}

fn get_matrix(c: &mut Cursor<'_>) -> Result<FeatureMatrix, WalError> {
    let n = get_usize(c)?;
    let d = get_usize(c)?;
    if d == 0 && n > 0 {
        return Err(corrupt("matrix with rows but zero width"));
    }
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        let row = m.row_mut(i);
        for slot in row.iter_mut().take(d) {
            *slot = c.f32()?;
        }
    }
    Ok(m)
}

/// Serialize a checkpoint state (the bytes that go inside the checksummed
/// checkpoint frame).
pub(crate) fn encode(s: &CheckpointState) -> Vec<u8> {
    let mut out = Vec::new();
    put_u8(&mut out, VERSION);
    put_u64(&mut out, s.wal_seq);
    put_usize(&mut out, s.d);
    // config
    put_usize(&mut out, s.k);
    put_usize(&mut out, s.ss.r);
    put_f64(&mut out, s.ss.c);
    put_u64(&mut out, s.ss.seed);
    put_u8(
        &mut out,
        match s.ss.sampling {
            Sampling::Uniform => 0,
            Sampling::Importance => 1,
        },
    );
    put_usize(&mut out, s.ss.min_keep);
    put_usize(&mut out, s.high_water);
    put_usize(&mut out, s.max_live);
    match &s.admission {
        None => put_u8(&mut out, 0),
        Some(p) => {
            put_u8(&mut out, 1);
            put_f64(&mut out, p.eps);
            put_usize(&mut out, p.max_thresholds);
        }
    }
    put_usize(&mut out, s.shards);
    put_f64(&mut out, s.intermediate_eps);
    put_usize(&mut out, s.reserve_hint);
    // counters / flags
    put_u64(&mut out, s.windows);
    put_u64(&mut out, s.ss_rounds);
    put_u64(&mut out, s.appends);
    put_u64(&mut out, s.admitted);
    put_u64(&mut out, s.evicted);
    put_bool(&mut out, s.closed);
    put_usize(&mut out, s.retained_len);
    put_usize(&mut out, s.buffer_len);
    // remap
    put_usize(&mut out, s.base);
    put_usize(&mut out, s.ext_to_int.len());
    for &e in &s.ext_to_int {
        put_u32(&mut out, e);
    }
    put_usize(&mut out, s.int_to_ext.len());
    for &e in &s.int_to_ext {
        put_usize(&mut out, e);
    }
    // filter
    match &s.filter {
        None => put_u8(&mut out, 0),
        Some(f) => {
            put_u8(&mut out, 1);
            put_f64(&mut out, f.max_singleton);
            put_usize(&mut out, f.peak_resident);
            put_usize(&mut out, f.sieves.len());
            for sv in &f.sieves {
                put_f64(&mut out, sv.tau);
                put_f64(&mut out, sv.value);
                put_usize(&mut out, sv.len);
                put_usize(&mut out, sv.cov.len());
                for &x in &sv.cov {
                    put_f32(&mut out, x);
                }
            }
        }
    }
    // store
    match &s.store {
        StorePayload::Features { concave, rows } => {
            put_u8(&mut out, 1);
            match concave {
                Concave::Sqrt => put_u8(&mut out, 0),
                Concave::Log1p => put_u8(&mut out, 1),
                Concave::Pow(p) => {
                    put_u8(&mut out, 2);
                    put_u32(&mut out, u32::from(*p));
                }
            }
            put_matrix(&mut out, rows);
        }
        StorePayload::Facility { crossover, t, build, rows, sparse } => {
            put_u8(&mut out, 2);
            put_usize(&mut out, *crossover);
            match t {
                None => put_u8(&mut out, 0),
                Some(t) => {
                    put_u8(&mut out, 1);
                    put_usize(&mut out, *t);
                }
            }
            match build {
                BuildStrategy::Exact => put_u8(&mut out, 0),
                BuildStrategy::Lsh { tables, bits } => {
                    put_u8(&mut out, 1);
                    put_u32(&mut out, *tables);
                    put_u32(&mut out, *bits);
                }
                BuildStrategy::Auto => put_u8(&mut out, 2),
            }
            put_matrix(&mut out, rows);
            match sparse {
                None => put_u8(&mut out, 0),
                Some(p) => {
                    put_u8(&mut out, 1);
                    put_usize(&mut out, p.n);
                    put_usize(&mut out, p.t);
                    match p.lsh {
                        None => put_u8(&mut out, 0),
                        Some((tables, bits, floor)) => {
                            put_u8(&mut out, 1);
                            put_u32(&mut out, tables);
                            put_u32(&mut out, bits);
                            put_u32(&mut out, floor);
                        }
                    }
                    put_usize(&mut out, p.len.len());
                    for &l in &p.len {
                        put_u32(&mut out, l);
                    }
                    put_usize(&mut out, p.cols.len());
                    for &c in &p.cols {
                        put_u32(&mut out, c);
                    }
                    // vals share cols' length (validated on decode)
                    for &v in &p.vals {
                        put_f32(&mut out, v);
                    }
                }
            }
        }
    }
    out
}

/// Parse a verified checkpoint payload back into a [`CheckpointState`].
/// Structural errors are `Corrupt`; deeper semantic validation (remap
/// invariants, store consistency) happens when the session is rebuilt.
pub(crate) fn decode(bytes: &[u8]) -> Result<CheckpointState, WalError> {
    let mut c = Cursor::new(bytes);
    let version = c.u8()?;
    if version != VERSION {
        return Err(corrupt(&format!("unsupported version {version}")));
    }
    let wal_seq = c.u64()?;
    let d = get_usize(&mut c)?;
    let k = get_usize(&mut c)?;
    let ss = SsParams {
        r: get_usize(&mut c)?,
        c: c.f64()?,
        seed: c.u64()?,
        sampling: match c.u8()? {
            0 => Sampling::Uniform,
            1 => Sampling::Importance,
            other => return Err(corrupt(&format!("bad sampling tag {other}"))),
        },
        min_keep: get_usize(&mut c)?,
    };
    let high_water = get_usize(&mut c)?;
    let max_live = get_usize(&mut c)?;
    let admission = match c.u8()? {
        0 => None,
        1 => Some(SieveParams {
            eps: c.f64()?,
            max_thresholds: get_usize(&mut c)?,
        }),
        other => return Err(corrupt(&format!("bad admission tag {other}"))),
    };
    let shards = get_usize(&mut c)?;
    let intermediate_eps = c.f64()?;
    let reserve_hint = get_usize(&mut c)?;
    let windows = c.u64()?;
    let ss_rounds = c.u64()?;
    let appends = c.u64()?;
    let admitted = c.u64()?;
    let evicted = c.u64()?;
    let closed = get_bool(&mut c)?;
    let retained_len = get_usize(&mut c)?;
    let buffer_len = get_usize(&mut c)?;
    let base = get_usize(&mut c)?;
    let fwd_len = get_usize(&mut c)?;
    let mut ext_to_int = Vec::with_capacity(fwd_len.min(bytes.len()));
    for _ in 0..fwd_len {
        ext_to_int.push(c.u32()?);
    }
    let bwd_len = get_usize(&mut c)?;
    let mut int_to_ext = Vec::with_capacity(bwd_len.min(bytes.len()));
    for _ in 0..bwd_len {
        int_to_ext.push(get_usize(&mut c)?);
    }
    let filter = match c.u8()? {
        0 => None,
        1 => {
            let max_singleton = c.f64()?;
            let peak_resident = get_usize(&mut c)?;
            let n_sieves = get_usize(&mut c)?;
            let mut sieves = Vec::with_capacity(n_sieves.min(bytes.len()));
            for _ in 0..n_sieves {
                let tau = c.f64()?;
                let value = c.f64()?;
                let len = get_usize(&mut c)?;
                let cov_len = get_usize(&mut c)?;
                let mut cov = Vec::with_capacity(cov_len.min(bytes.len()));
                for _ in 0..cov_len {
                    cov.push(c.f32()?);
                }
                sieves.push(SievePayload { tau, value, len, cov });
            }
            Some(FilterPayload { max_singleton, peak_resident, sieves })
        }
        other => return Err(corrupt(&format!("bad filter tag {other}"))),
    };
    let store = match c.u8()? {
        1 => {
            let concave = match c.u8()? {
                0 => Concave::Sqrt,
                1 => Concave::Log1p,
                2 => {
                    let p = c.u32()?;
                    let p = u16::try_from(p).map_err(|_| corrupt("Pow exponent overflow"))?;
                    Concave::Pow(p)
                }
                other => return Err(corrupt(&format!("bad concave tag {other}"))),
            };
            let rows = get_matrix(&mut c)?;
            StorePayload::Features { concave, rows }
        }
        2 => {
            let crossover = get_usize(&mut c)?;
            let t = match c.u8()? {
                0 => None,
                1 => Some(get_usize(&mut c)?),
                other => return Err(corrupt(&format!("bad t tag {other}"))),
            };
            let build = match c.u8()? {
                0 => BuildStrategy::Exact,
                1 => BuildStrategy::Lsh { tables: c.u32()?, bits: c.u32()? },
                2 => BuildStrategy::Auto,
                other => return Err(corrupt(&format!("bad build-strategy tag {other}"))),
            };
            let rows = get_matrix(&mut c)?;
            let sparse = match c.u8()? {
                0 => None,
                1 => {
                    let n = get_usize(&mut c)?;
                    let t = get_usize(&mut c)?;
                    let lsh = match c.u8()? {
                        0 => None,
                        1 => Some((c.u32()?, c.u32()?, c.u32()?)),
                        other => return Err(corrupt(&format!("bad lsh tag {other}"))),
                    };
                    let len_len = get_usize(&mut c)?;
                    let mut len = Vec::with_capacity(len_len.min(bytes.len()));
                    for _ in 0..len_len {
                        len.push(c.u32()?);
                    }
                    let slots = get_usize(&mut c)?;
                    let mut cols = Vec::with_capacity(slots.min(bytes.len()));
                    for _ in 0..slots {
                        cols.push(c.u32()?);
                    }
                    let mut vals = Vec::with_capacity(slots.min(bytes.len()));
                    for _ in 0..slots {
                        vals.push(c.f32()?);
                    }
                    Some(SparseParts { n, t, len, cols, vals, lsh })
                }
                other => return Err(corrupt(&format!("bad sparse tag {other}"))),
            };
            StorePayload::Facility { crossover, t, build, rows, sparse }
        }
        other => return Err(corrupt(&format!("bad store tag {other}"))),
    };
    c.done()?;
    Ok(CheckpointState {
        wal_seq,
        d,
        k,
        ss,
        high_water,
        max_live,
        admission,
        shards,
        intermediate_eps,
        reserve_hint,
        windows,
        ss_rounds,
        appends,
        admitted,
        evicted,
        closed,
        retained_len,
        buffer_len,
        base,
        ext_to_int,
        int_to_ext,
        filter,
        store,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> CheckpointState {
        let mut rows = FeatureMatrix::zeros(0, 3);
        rows.push_row(&[1.0, 0.5, 0.25]);
        rows.push_row(&[0.0, 2.0, 0.125]);
        CheckpointState {
            wal_seq: 42,
            d: 3,
            k: 4,
            ss: SsParams {
                r: 8,
                c: 8.0,
                seed: 7,
                sampling: Sampling::Importance,
                min_keep: 2,
            },
            high_water: 100,
            max_live: 0,
            admission: Some(SieveParams { eps: 0.08, max_thresholds: 50 }),
            shards: 3,
            intermediate_eps: 0.2,
            reserve_hint: 64,
            windows: 5,
            ss_rounds: 11,
            appends: 200,
            admitted: 150,
            evicted: 80,
            closed: false,
            retained_len: 1,
            buffer_len: 1,
            base: 9,
            ext_to_int: vec![0, u32::MAX, 1],
            int_to_ext: vec![9, 11],
            filter: Some(FilterPayload {
                max_singleton: 1.5,
                peak_resident: 12,
                sieves: vec![SievePayload {
                    tau: 2.25,
                    value: 1.125,
                    len: 2,
                    cov: vec![0.5, 0.0, 1.5],
                }],
            }),
            store: StorePayload::Features { concave: Concave::Pow(3), rows },
        }
    }

    #[test]
    fn round_trip_is_exact() {
        let s = sample_state();
        let bytes = encode(&s);
        let r = decode(&bytes).unwrap();
        assert_eq!(r.wal_seq, 42);
        assert_eq!(r.d, 3);
        assert_eq!(r.k, 4);
        assert_eq!(r.ss.r, 8);
        assert_eq!(r.ss.seed, 7);
        assert!(matches!(r.ss.sampling, Sampling::Importance));
        assert_eq!(r.ss.min_keep, 2);
        assert_eq!(r.high_water, 100);
        let p = r.admission.unwrap();
        assert_eq!(p.eps.to_bits(), 0.08f64.to_bits());
        assert_eq!(p.max_thresholds, 50);
        assert_eq!(r.windows, 5);
        assert_eq!(r.appends, 200);
        assert_eq!(r.base, 9);
        assert_eq!(r.ext_to_int, vec![0, u32::MAX, 1]);
        assert_eq!(r.int_to_ext, vec![9, 11]);
        let f = r.filter.unwrap();
        assert_eq!(f.peak_resident, 12);
        assert_eq!(f.sieves.len(), 1);
        assert_eq!(f.sieves[0].cov, vec![0.5, 0.0, 1.5]);
        match r.store {
            StorePayload::Features { concave: Concave::Pow(3), rows } => {
                assert_eq!(rows.n(), 2);
                assert_eq!(rows.d(), 3);
                assert_eq!(rows.row(1), &[0.0, 2.0, 0.125]);
            }
            _ => panic!("store payload mangled"),
        }
    }

    #[test]
    fn facility_store_round_trips() {
        let mut rows = FeatureMatrix::zeros(0, 2);
        rows.push_row(&[1.0, 0.0]);
        rows.push_row(&[0.0, 1.0]);
        let mut s = sample_state();
        s.admission = None;
        s.filter = None;
        s.store = StorePayload::Facility {
            crossover: 4096,
            t: Some(16),
            build: BuildStrategy::Lsh { tables: 6, bits: 9 },
            rows,
            sparse: Some(SparseParts {
                n: 2,
                t: 1,
                len: vec![2, 1],
                cols: vec![0, 1, 1, 0],
                vals: vec![1.0, 0.5, 1.0, 0.0],
                lsh: Some((6, 9, 12)),
            }),
        };
        let r = decode(&encode(&s)).unwrap();
        match r.store {
            StorePayload::Facility {
                crossover: 4096,
                t: Some(16),
                build: BuildStrategy::Lsh { tables: 6, bits: 9 },
                rows,
                sparse: Some(p),
            } => {
                assert_eq!(rows.n(), 2);
                assert_eq!(p.n, 2);
                assert_eq!(p.t, 1);
                assert_eq!(p.len, vec![2, 1]);
                assert_eq!(p.cols, vec![0, 1, 1, 0]);
                assert_eq!(p.vals, vec![1.0, 0.5, 1.0, 0.0]);
                assert_eq!(p.lsh, Some((6, 9, 12)));
            }
            _ => panic!("facility payload mangled"),
        }
    }

    #[test]
    fn facility_store_without_lsh_round_trips() {
        let mut rows = FeatureMatrix::zeros(0, 2);
        rows.push_row(&[1.0, 0.0]);
        let mut s = sample_state();
        s.store = StorePayload::Facility {
            crossover: 0,
            t: None,
            build: BuildStrategy::Auto,
            rows,
            sparse: Some(SparseParts {
                n: 1,
                t: 0,
                len: vec![1],
                cols: vec![0],
                vals: vec![1.0],
                lsh: None,
            }),
        };
        let r = decode(&encode(&s)).unwrap();
        match r.store {
            StorePayload::Facility {
                build: BuildStrategy::Auto, t: None, sparse: Some(p), ..
            } => assert_eq!(p.lsh, None),
            _ => panic!("facility payload mangled"),
        }
    }

    #[test]
    fn truncated_and_trailing_bytes_are_corrupt() {
        let bytes = encode(&sample_state());
        for cut in [0, 1, 5, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                matches!(decode(&bytes[..cut]), Err(WalError::Corrupt(_))),
                "cut {cut} must be corrupt"
            );
        }
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(matches!(decode(&padded), Err(WalError::Corrupt(_))));
        let mut wrong_version = bytes;
        wrong_version[0] = 99;
        assert!(matches!(decode(&wrong_version), Err(WalError::Corrupt(_))));
    }
}
