//! Write-ahead log and durable-store abstraction for stream sessions.
//!
//! A durable session owns a [`DurableStore`] with two named blobs:
//!
//! * `"wal"` — an append-only sequence of length-prefixed, checksummed
//!   records (admitted batches, compaction decisions, close markers);
//! * `"checkpoint"` — the latest atomically-replaced full-state snapshot
//!   (encoded by `stream::checkpoint`).
//!
//! Record framing is `[body_len: u32 LE][body][fnv1a64(body): u64 LE]`
//! where `body = [kind: u8][seq: u64 LE][payload]`. Sequence numbers are
//! monotone across the session's whole life (they survive checkpoint
//! truncation), which lets recovery skip records already covered by the
//! checkpoint after a crash between checkpoint-write and WAL-truncate.
//!
//! The protocol is *log-before-apply*: a batch is framed, appended, and
//! flushed before the session mutates any in-memory state, so every
//! durable prefix of the WAL corresponds to a reachable session state.
//! Torn tails (a crash mid-append) are detected by the length prefix /
//! trailing checksum and truncated on recovery; a checksum-valid but
//! semantically impossible record is *corruption* and quarantines the
//! session with a typed error instead of a panic.
//!
//! Everything here is hand-rolled over `std` — no new dependencies.

use std::collections::HashMap;
use std::io::Write;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Blob name of the write-ahead log inside a [`DurableStore`].
pub(crate) const WAL: &str = "wal";
/// Blob name of the checkpoint inside a [`DurableStore`].
pub(crate) const CHECKPOINT: &str = "checkpoint";

/// Magic prefix of a checkpoint blob: `"SSCP"` little-endian.
pub(crate) const CHECKPOINT_MAGIC: u32 = 0x5353_4350;

pub(crate) const KIND_APPEND: u8 = 1;
pub(crate) const KIND_COMPACT: u8 = 2;
pub(crate) const KIND_CLOSE: u8 = 3;

/// Minimum body size: kind (1) + seq (8).
const MIN_BODY: usize = 9;

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Failures surfaced by the durability layer.
///
/// `Io` is an environmental failure (disk full, permission, injected
/// fault); `Corrupt` means the durable bytes violate the protocol in a
/// way truncation cannot repair — the session must be quarantined.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalError {
    /// Underlying storage failed.
    Io(String),
    /// The durable bytes are internally inconsistent (bad checksum,
    /// malformed payload, sequence gap, bad checkpoint magic, ...).
    Corrupt(String),
}

impl std::fmt::Display for WalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WalError::Io(msg) => write!(f, "durable store I/O error: {msg}"),
            WalError::Corrupt(msg) => write!(f, "durable log corrupt: {msg}"),
        }
    }
}

impl std::error::Error for WalError {}

// ---------------------------------------------------------------------------
// Checksum + little-endian codec helpers (shared with stream::checkpoint)
// ---------------------------------------------------------------------------

/// FNV-1a 64-bit — tiny, dependency-free, and plenty for torn/bit-rot
/// detection (this is an integrity check, not an adversarial MAC).
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h = (h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

pub(crate) fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

pub(crate) fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// Bounds-checked little-endian reader over a byte slice. Every short
/// read is a `Corrupt` error (the caller decides whether the enclosing
/// context makes it torn instead).
pub(crate) struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], WalError> {
        if self.remaining() < n {
            return Err(WalError::Corrupt(format!(
                "short read: wanted {n} bytes, {} remain",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, WalError> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32, WalError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, WalError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub(crate) fn f32(&mut self) -> Result<f32, WalError> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, WalError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub(crate) fn done(&self) -> Result<(), WalError> {
        if self.remaining() != 0 {
            return Err(WalError::Corrupt(format!(
                "{} trailing bytes after payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// DurableStore: the pluggable byte-blob backend
// ---------------------------------------------------------------------------

/// A tiny named-blob store the durability layer writes through. Two
/// implementations ship: [`FileStore`] (real files) and [`MemStore`]
/// (tests), plus [`FaultStore`], a deterministic fault injector that
/// wraps either.
///
/// Contract: `append` extends a blob (creating it), `write_atomic`
/// replaces a blob all-or-nothing (a crash mid-call leaves the *old*
/// content), `truncate` shortens to `len` bytes, `flush` makes prior
/// writes to the blob durable, and `read_all` returns `None` for a
/// blob that was never written.
pub trait DurableStore: Send {
    fn read_all(&mut self, name: &str) -> Result<Option<Vec<u8>>, WalError>;
    fn append(&mut self, name: &str, bytes: &[u8]) -> Result<(), WalError>;
    fn write_atomic(&mut self, name: &str, bytes: &[u8]) -> Result<(), WalError>;
    fn truncate(&mut self, name: &str, len: u64) -> Result<(), WalError>;
    fn flush(&mut self, name: &str) -> Result<(), WalError>;
}

fn io_err(ctx: &str, e: std::io::Error) -> WalError {
    WalError::Io(format!("{ctx}: {e}"))
}

/// File-backed [`DurableStore`]: one file per blob under a directory.
///
/// Flush policy: `append` only buffers through the OS (`write_all`);
/// [`Durability`] calls `flush` — an `fsync` — once per logical record,
/// so a record is durable before the session mutates in-memory state.
/// `write_atomic` goes through a `.tmp` + `fsync` + `rename` so the
/// checkpoint blob is replaced all-or-nothing.
pub struct FileStore {
    dir: PathBuf,
}

impl FileStore {
    /// Open (creating if needed) a store rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self, WalError> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).map_err(|e| io_err("create store dir", e))?;
        Ok(Self { dir })
    }

    /// Directory this store lives in.
    pub fn dir(&self) -> &std::path::Path {
        &self.dir
    }

    fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }
}

impl DurableStore for FileStore {
    fn read_all(&mut self, name: &str) -> Result<Option<Vec<u8>>, WalError> {
        match std::fs::read(self.path(name)) {
            Ok(bytes) => Ok(Some(bytes)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(io_err("read blob", e)),
        }
    }

    fn append(&mut self, name: &str, bytes: &[u8]) -> Result<(), WalError> {
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .create(true)
            .open(self.path(name))
            .map_err(|e| io_err("open for append", e))?;
        f.write_all(bytes).map_err(|e| io_err("append", e))
    }

    fn write_atomic(&mut self, name: &str, bytes: &[u8]) -> Result<(), WalError> {
        let tmp = self.path(&format!("{name}.tmp"));
        {
            let mut f = std::fs::File::create(&tmp).map_err(|e| io_err("create tmp", e))?;
            f.write_all(bytes).map_err(|e| io_err("write tmp", e))?;
            f.sync_all().map_err(|e| io_err("sync tmp", e))?;
        }
        std::fs::rename(&tmp, self.path(name)).map_err(|e| io_err("rename tmp", e))?;
        // Make the rename itself durable (Linux: fsync the directory).
        if let Ok(d) = std::fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
        Ok(())
    }

    fn truncate(&mut self, name: &str, len: u64) -> Result<(), WalError> {
        let f = std::fs::OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(false)
            .open(self.path(name))
            .map_err(|e| io_err("open for truncate", e))?;
        f.set_len(len).map_err(|e| io_err("truncate", e))?;
        f.sync_all().map_err(|e| io_err("sync truncate", e))
    }

    fn flush(&mut self, name: &str) -> Result<(), WalError> {
        match std::fs::File::open(self.path(name)) {
            Ok(f) => f.sync_all().map_err(|e| io_err("fsync", e)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(io_err("open for fsync", e)),
        }
    }
}

/// In-memory [`DurableStore`] for tests. Cloning yields a handle onto
/// the *same* blobs, so a test can keep a handle, hand a clone to a
/// session (possibly wrapped in a [`FaultStore`]), "crash" by dropping
/// the session, and recover from what survived.
#[derive(Clone, Default)]
pub struct MemStore {
    files: Arc<Mutex<HashMap<String, Vec<u8>>>>,
}

impl MemStore {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<String, Vec<u8>>> {
        // A poisoned test store just means some other test thread
        // panicked; the bytes themselves are still coherent.
        self.files.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Raw bytes of a blob (test inspection).
    pub fn raw(&self, name: &str) -> Option<Vec<u8>> {
        self.lock().get(name).cloned()
    }

    /// Overwrite a blob wholesale (test setup).
    pub fn set_raw(&self, name: &str, bytes: Vec<u8>) {
        self.lock().insert(name.to_string(), bytes);
    }

    /// Flip one byte in place — simulates bit rot / checksum corruption.
    pub fn flip_byte(&self, name: &str, idx: usize) {
        let mut files = self.lock();
        if let Some(buf) = files.get_mut(name) {
            if let Some(b) = buf.get_mut(idx) {
                *b ^= 0xff;
            }
        }
    }

    /// Drop the last `n` bytes of a blob — simulates a torn tail.
    pub fn chop_tail(&self, name: &str, n: usize) {
        let mut files = self.lock();
        if let Some(buf) = files.get_mut(name) {
            let keep = buf.len().saturating_sub(n);
            buf.truncate(keep);
        }
    }

    /// Blob length in bytes (0 if absent).
    pub fn len(&self, name: &str) -> usize {
        self.lock().get(name).map_or(0, Vec::len)
    }

    /// True when no blob has ever been written.
    pub fn is_empty(&self) -> bool {
        self.lock().is_empty()
    }
}

impl DurableStore for MemStore {
    fn read_all(&mut self, name: &str) -> Result<Option<Vec<u8>>, WalError> {
        Ok(self.lock().get(name).cloned())
    }

    fn append(&mut self, name: &str, bytes: &[u8]) -> Result<(), WalError> {
        self.lock()
            .entry(name.to_string())
            .or_default()
            .extend_from_slice(bytes);
        Ok(())
    }

    fn write_atomic(&mut self, name: &str, bytes: &[u8]) -> Result<(), WalError> {
        self.lock().insert(name.to_string(), bytes.to_vec());
        Ok(())
    }

    fn truncate(&mut self, name: &str, len: u64) -> Result<(), WalError> {
        let mut files = self.lock();
        let buf = files.entry(name.to_string()).or_default();
        let keep = (len as usize).min(buf.len());
        buf.truncate(keep);
        Ok(())
    }

    fn flush(&mut self, _name: &str) -> Result<(), WalError> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// FaultStore: deterministic crash / torn-write / short-read injection
// ---------------------------------------------------------------------------

/// Deterministic fault-injection wrapper around any [`DurableStore`].
///
/// The model is an *op budget*: every mutating call (`append`,
/// `write_atomic`, `truncate`) increments a shared counter; once the
/// counter passes `fail_after_ops`, mutations are silently dropped —
/// the image a real crash at that instant would leave behind. The
/// wrapped session keeps running in memory (the test discards it), and
/// recovery then sees exactly the durable prefix.
///
/// Options:
/// * `with_torn_tail(b)` — the first over-budget `append` lands only
///   its first `b` bytes, producing a torn record;
/// * `with_error_on_fault()` — over-budget mutations return
///   `WalError::Io` instead of silently dropping (exercises the
///   quarantine-on-I/O-error path);
/// * `with_read_cap(n)` — `read_all` returns at most `n` bytes
///   (a short read at recovery time).
///
/// `flush` never consumes budget and never faults: durability points
/// are modeled at the write that precedes them, keeping kill-point
/// enumeration dense and deterministic.
pub struct FaultStore {
    inner: Box<dyn DurableStore>,
    ops: Arc<AtomicU64>,
    fail_after_ops: Option<u64>,
    torn_tail_bytes: Option<usize>,
    torn_done: bool,
    error_on_fault: bool,
    read_cap: Option<usize>,
}

impl FaultStore {
    /// Wrap `inner` with no faults armed (pure pass-through + op count).
    pub fn new(inner: Box<dyn DurableStore>) -> Self {
        Self {
            inner,
            ops: Arc::new(AtomicU64::new(0)),
            fail_after_ops: None,
            torn_tail_bytes: None,
            torn_done: false,
            error_on_fault: false,
            read_cap: None,
        }
    }

    /// Crash after `n` mutating ops: ops `0..n` land, the rest vanish.
    pub fn fail_after(mut self, n: u64) -> Self {
        self.fail_after_ops = Some(n);
        self
    }

    /// First over-budget append lands a `bytes`-byte prefix (torn tail).
    pub fn with_torn_tail(mut self, bytes: usize) -> Self {
        self.torn_tail_bytes = Some(bytes);
        self
    }

    /// Report over-budget mutations as `WalError::Io` instead of
    /// silently dropping them.
    pub fn with_error_on_fault(mut self) -> Self {
        self.error_on_fault = true;
        self
    }

    /// Cap `read_all` results at `n` bytes (short read).
    pub fn with_read_cap(mut self, n: usize) -> Self {
        self.read_cap = Some(n);
        self
    }

    /// Shared handle onto the mutating-op counter. Clone it *before*
    /// boxing the store to observe/record op positions from the test.
    pub fn ops_counter(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.ops)
    }

    /// Counts one mutating op; true while within budget.
    fn within_budget(&mut self) -> bool {
        let c = self.ops.fetch_add(1, Ordering::SeqCst);
        match self.fail_after_ops {
            None => true,
            Some(n) => c < n,
        }
    }

    fn fault_result(&self) -> Result<(), WalError> {
        if self.error_on_fault {
            Err(WalError::Io("injected fault".into()))
        } else {
            Ok(())
        }
    }
}

impl DurableStore for FaultStore {
    fn read_all(&mut self, name: &str) -> Result<Option<Vec<u8>>, WalError> {
        let out = self.inner.read_all(name)?;
        Ok(match (out, self.read_cap) {
            (Some(mut bytes), Some(cap)) => {
                bytes.truncate(cap);
                Some(bytes)
            }
            (out, _) => out,
        })
    }

    fn append(&mut self, name: &str, bytes: &[u8]) -> Result<(), WalError> {
        if self.within_budget() {
            return self.inner.append(name, bytes);
        }
        if let (Some(b), false) = (self.torn_tail_bytes, self.torn_done) {
            self.torn_done = true;
            let cut = b.min(bytes.len());
            self.inner.append(name, &bytes[..cut])?;
        }
        self.fault_result()
    }

    fn write_atomic(&mut self, name: &str, bytes: &[u8]) -> Result<(), WalError> {
        if self.within_budget() {
            return self.inner.write_atomic(name, bytes);
        }
        // Atomic replace: an over-budget write leaves the old blob.
        self.fault_result()
    }

    fn truncate(&mut self, name: &str, len: u64) -> Result<(), WalError> {
        if self.within_budget() {
            return self.inner.truncate(name, len);
        }
        self.fault_result()
    }

    fn flush(&mut self, name: &str) -> Result<(), WalError> {
        self.inner.flush(name)
    }
}

// ---------------------------------------------------------------------------
// Records
// ---------------------------------------------------------------------------

/// A parsed WAL record.
#[derive(Debug, Clone, PartialEq)]
pub(crate) struct WalRecord {
    pub(crate) seq: u64,
    pub(crate) kind: RecordKind,
}

#[derive(Debug, Clone, PartialEq)]
pub(crate) enum RecordKind {
    /// Raw admitted-batch floats, row-major; width is the session's `d`.
    Append(Vec<f32>),
    /// A window compaction: SS ran `rounds` rounds and kept these live
    /// offsets (ascending). A replay optimization — replay falls back
    /// to re-running SS live (bit-identical) if the record is unusable.
    Compact { rounds: u32, kept: Vec<u32> },
    /// The session was closed cleanly.
    Close,
}

fn frame(kind: u8, seq: u64, payload: &[u8]) -> Vec<u8> {
    let mut body = Vec::with_capacity(MIN_BODY + payload.len());
    put_u8(&mut body, kind);
    put_u64(&mut body, seq);
    body.extend_from_slice(payload);
    let mut out = Vec::with_capacity(4 + body.len() + 8);
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    put_u64(&mut out, fnv1a64(&body));
    out
}

/// Frame a checkpoint payload: `[magic][len][payload][fnv64(payload)]`.
pub(crate) fn frame_checkpoint(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + payload.len() + 8);
    put_u32(&mut out, CHECKPOINT_MAGIC);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(payload);
    put_u64(&mut out, fnv1a64(payload));
    out
}

fn parse_checkpoint(bytes: &[u8]) -> Result<Vec<u8>, WalError> {
    let mut c = Cursor::new(bytes);
    let magic = c.u32().map_err(|_| {
        WalError::Corrupt("checkpoint blob shorter than its header".into())
    })?;
    if magic != CHECKPOINT_MAGIC {
        return Err(WalError::Corrupt(format!(
            "bad checkpoint magic 0x{magic:08x}"
        )));
    }
    let len = c.u32()? as usize;
    let payload = c
        .take(len)
        .map_err(|_| WalError::Corrupt("checkpoint payload truncated".into()))?;
    let sum = c.u64()?;
    c.done()?;
    if sum != fnv1a64(payload) {
        return Err(WalError::Corrupt("checkpoint checksum mismatch".into()));
    }
    Ok(payload.to_vec())
}

fn parse_body(body: &[u8]) -> Result<WalRecord, WalError> {
    let mut c = Cursor::new(body);
    let kind = c.u8()?;
    let seq = c.u64()?;
    let kind = match kind {
        KIND_APPEND => {
            let n = c.u32()? as usize;
            let mut rows = Vec::with_capacity(n);
            for _ in 0..n {
                rows.push(c.f32()?);
            }
            RecordKind::Append(rows)
        }
        KIND_COMPACT => {
            let rounds = c.u32()?;
            let count = c.u32()? as usize;
            let mut kept = Vec::with_capacity(count);
            for _ in 0..count {
                kept.push(c.u32()?);
            }
            RecordKind::Compact { rounds, kept }
        }
        KIND_CLOSE => RecordKind::Close,
        other => {
            return Err(WalError::Corrupt(format!(
                "unknown record kind {other} at seq {seq}"
            )))
        }
    };
    c.done()?;
    Ok(WalRecord { seq, kind })
}

// ---------------------------------------------------------------------------
// Load: checkpoint + WAL parse with torn-tail repair
// ---------------------------------------------------------------------------

/// Everything recovery needs, parsed and integrity-checked.
pub(crate) struct LoadedLog {
    /// Verified checkpoint payload bytes, if a checkpoint exists.
    pub(crate) checkpoint: Option<Vec<u8>>,
    /// Contiguous-seq records that survived in the WAL.
    pub(crate) records: Vec<WalRecord>,
    /// 1 if a torn tail was found and truncated away, else 0.
    pub(crate) torn_tail_truncations: u64,
}

/// Read and verify the checkpoint and WAL from `store`, truncating a
/// torn tail in place. `Err(Corrupt)` means the session must be
/// quarantined; torn tails are expected after a crash and repaired.
pub(crate) fn load(store: &mut dyn DurableStore) -> Result<LoadedLog, WalError> {
    let checkpoint = match store.read_all(CHECKPOINT)? {
        Some(bytes) => Some(parse_checkpoint(&bytes)?),
        None => None,
    };

    let wal = store.read_all(WAL)?.unwrap_or_default();
    let mut records = Vec::new();
    let mut torn = 0u64;
    let mut pos = 0usize;
    while pos < wal.len() {
        let rem = wal.len() - pos;
        // A partially-written length prefix is torn by definition; a
        // fully-written one whose frame overruns the file is torn too —
        // this also catches a garbage length value, because a complete
        // frame is always present for every record the session flushed.
        if rem < 4 {
            torn = 1;
            break;
        }
        let len =
            u32::from_le_bytes([wal[pos], wal[pos + 1], wal[pos + 2], wal[pos + 3]]) as usize;
        if rem < 4 + len + 8 {
            torn = 1;
            break;
        }
        if len < MIN_BODY {
            return Err(WalError::Corrupt(format!(
                "record at byte {pos} has impossible body length {len}"
            )));
        }
        let body = &wal[pos + 4..pos + 4 + len];
        let sum = u64::from_le_bytes(
            wal[pos + 4 + len..pos + 4 + len + 8].try_into().unwrap(),
        );
        if sum != fnv1a64(body) {
            return Err(WalError::Corrupt(format!(
                "record checksum mismatch at byte {pos}"
            )));
        }
        let rec = parse_body(body)?;
        if let Some(prev) = records.last() {
            let prev: &WalRecord = prev;
            if rec.seq != prev.seq + 1 {
                return Err(WalError::Corrupt(format!(
                    "sequence gap: record {} follows {}",
                    rec.seq, prev.seq
                )));
            }
        }
        records.push(rec);
        pos += 4 + len + 8;
    }
    if torn == 1 {
        store.truncate(WAL, pos as u64)?;
        store.flush(WAL)?;
    }
    Ok(LoadedLog {
        checkpoint,
        records,
        torn_tail_truncations: torn,
    })
}

// ---------------------------------------------------------------------------
// Durability: the session-side write path
// ---------------------------------------------------------------------------

/// When WAL records are flushed (fsync'd) to the store — the
/// group-commit knob. Records are always *appended* immediately, in
/// order; the policy governs only how many appends share one flush.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FlushPolicy {
    /// Flush after every record: zero data-loss window on a machine
    /// crash, one fsync per record. The default, and what the
    /// crash-exactness tests assume.
    #[default]
    EveryRecord,
    /// Group commit: flush once per `N` records (`0` behaves as `1`).
    /// A *process* crash loses nothing — the records were written, the
    /// OS page cache survives the process — but a *machine* crash can
    /// lose up to `N − 1` unflushed records. Recovery semantics are
    /// unchanged either way: the WAL parser stops at the first torn or
    /// missing record, exactly as with a torn single flush.
    EveryN(u32),
}

/// Tuning for a durable session.
#[derive(Debug, Clone, Copy)]
pub struct DurabilityConfig {
    /// Auto-checkpoint after this many WAL records (appends, compacts,
    /// closes) since the last checkpoint; `0` disables auto-checkpoints
    /// (explicit `checkpoint_now` / `submit_checkpoint` only). The
    /// replayed-on-recovery WAL tail is bounded by this interval.
    pub checkpoint_interval: u64,
    /// Group-commit flush policy (see [`FlushPolicy`]); priced in
    /// `perf_durability`'s overhead column.
    pub flush_policy: FlushPolicy,
}

impl Default for DurabilityConfig {
    fn default() -> Self {
        Self {
            checkpoint_interval: 64,
            flush_policy: FlushPolicy::EveryRecord,
        }
    }
}

impl DurabilityConfig {
    pub fn with_checkpoint_interval(mut self, every: u64) -> Self {
        self.checkpoint_interval = every;
        self
    }

    pub fn with_flush_policy(mut self, policy: FlushPolicy) -> Self {
        self.flush_policy = policy;
        self
    }
}

/// The per-session durability state: a boxed store, the next record
/// sequence number, and the record count since the last checkpoint.
/// Owned by `StreamSession`; all writes happen under the session lock,
/// so WAL order always matches apply order.
pub(crate) struct Durability {
    store: Box<dyn DurableStore>,
    cfg: DurabilityConfig,
    next_seq: u64,
    since_checkpoint: u64,
    /// Records appended since the last flush (group commit accounting).
    unflushed: u64,
    quarantined: Option<String>,
}

impl Durability {
    pub(crate) fn new(store: Box<dyn DurableStore>, cfg: DurabilityConfig) -> Self {
        Self {
            store,
            cfg,
            next_seq: 0,
            since_checkpoint: 0,
            unflushed: 0,
            quarantined: None,
        }
    }

    /// Re-attach after recovery: `next_seq` continues the parsed log,
    /// `since_checkpoint` is the replayed tail length.
    pub(crate) fn resume(
        store: Box<dyn DurableStore>,
        cfg: DurabilityConfig,
        next_seq: u64,
        since_checkpoint: u64,
    ) -> Self {
        Self {
            store,
            cfg,
            next_seq,
            since_checkpoint,
            unflushed: 0,
            quarantined: None,
        }
    }

    pub(crate) fn next_seq(&self) -> u64 {
        self.next_seq
    }

    pub(crate) fn quarantined(&self) -> Option<&str> {
        self.quarantined.as_deref()
    }

    pub(crate) fn quarantine(&mut self, reason: String) {
        if self.quarantined.is_none() {
            self.quarantined = Some(reason);
        }
    }

    fn log(&mut self, kind: u8, payload: &[u8]) -> Result<(), WalError> {
        let framed = frame(kind, self.next_seq, payload);
        self.store.append(WAL, &framed)?;
        self.next_seq += 1;
        self.since_checkpoint += 1;
        self.unflushed += 1;
        let due = match self.cfg.flush_policy {
            FlushPolicy::EveryRecord => true,
            FlushPolicy::EveryN(n) => self.unflushed >= n.max(1) as u64,
        };
        if due {
            self.store.flush(WAL)?;
            self.unflushed = 0;
        }
        Ok(())
    }

    /// Log an admitted-batch record (the raw rows, pre-admission: even
    /// rejected rows advance sieve and id-remap state, so replay needs
    /// the whole batch).
    pub(crate) fn log_append(&mut self, rows: &[f32]) -> Result<(), WalError> {
        let mut payload = Vec::with_capacity(4 + rows.len() * 4);
        put_u32(&mut payload, rows.len() as u32);
        for &v in rows {
            put_f32(&mut payload, v);
        }
        self.log(KIND_APPEND, &payload)
    }

    /// Log a window compaction (SS `rounds` + ascending kept offsets).
    pub(crate) fn log_compact(&mut self, rounds: usize, kept: &[usize]) -> Result<(), WalError> {
        let mut payload = Vec::with_capacity(8 + kept.len() * 4);
        put_u32(&mut payload, rounds as u32);
        put_u32(&mut payload, kept.len() as u32);
        for &k in kept {
            put_u32(&mut payload, k as u32);
        }
        self.log(KIND_COMPACT, &payload)
    }

    /// Log a clean close. Force-flushes regardless of policy: a close
    /// record exists to make the shutdown durable.
    pub(crate) fn log_close(&mut self) -> Result<(), WalError> {
        self.log(KIND_CLOSE, &[])?;
        if self.unflushed > 0 {
            self.store.flush(WAL)?;
            self.unflushed = 0;
        }
        Ok(())
    }

    /// True when the auto-checkpoint interval has elapsed.
    pub(crate) fn checkpoint_due(&self) -> bool {
        self.cfg.checkpoint_interval > 0 && self.since_checkpoint >= self.cfg.checkpoint_interval
    }

    /// Atomically replace the checkpoint blob, then reset the WAL. A
    /// crash between the two is safe: recovery skips records whose seq
    /// is below the checkpoint's embedded `wal_seq`. Returns the
    /// checkpoint blob size in bytes.
    pub(crate) fn write_checkpoint(&mut self, payload: &[u8]) -> Result<usize, WalError> {
        let framed = frame_checkpoint(payload);
        let bytes = framed.len();
        self.store.write_atomic(CHECKPOINT, &framed)?;
        self.store.flush(CHECKPOINT)?;
        self.store.truncate(WAL, 0)?;
        self.store.flush(WAL)?;
        self.since_checkpoint = 0;
        // the WAL was just truncated — nothing unflushed remains
        self.unflushed = 0;
        Ok(bytes)
    }

    /// Reclaim the boxed store (used when recovery hands ownership
    /// through a temporary `Durability`).
    #[allow(dead_code)]
    pub(crate) fn into_store(self) -> Box<dyn DurableStore> {
        self.store
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn mem() -> MemStore {
        MemStore::new()
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // Published FNV-1a 64 vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn wal_round_trips_all_record_kinds() {
        let store = mem();
        let mut d = Durability::new(Box::new(store.clone()), DurabilityConfig::default());
        d.log_append(&[1.0, 2.5, -0.0]).unwrap();
        d.log_compact(3, &[0, 2, 5]).unwrap();
        d.log_close().unwrap();
        assert_eq!(d.next_seq(), 3);

        let mut reader = store.clone();
        let loaded = load(&mut reader).unwrap();
        assert!(loaded.checkpoint.is_none());
        assert_eq!(loaded.torn_tail_truncations, 0);
        assert_eq!(loaded.records.len(), 3);
        assert_eq!(
            loaded.records[0].kind,
            RecordKind::Append(vec![1.0, 2.5, -0.0])
        );
        assert_eq!(
            loaded.records[1].kind,
            RecordKind::Compact {
                rounds: 3,
                kept: vec![0, 2, 5]
            }
        );
        assert_eq!(loaded.records[2].kind, RecordKind::Close);
        assert_eq!(loaded.records[2].seq, 2);
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let store = mem();
        let mut d = Durability::new(Box::new(store.clone()), DurabilityConfig::default());
        d.log_append(&[1.0, 2.0]).unwrap();
        d.log_append(&[3.0, 4.0]).unwrap();
        let full = store.len(WAL);
        // Tear anywhere inside the second record, including mid-prefix.
        for chop in 1..(full / 2) {
            let s = mem();
            s.set_raw(WAL, store.raw(WAL).unwrap());
            s.chop_tail(WAL, chop);
            let mut reader = s.clone();
            let loaded = load(&mut reader).unwrap();
            assert_eq!(loaded.torn_tail_truncations, 1, "chop {chop}");
            assert_eq!(loaded.records.len(), 1, "chop {chop}");
            // The file was repaired in place: a second load is clean.
            let again = load(&mut s.clone()).unwrap();
            assert_eq!(again.torn_tail_truncations, 0);
            assert_eq!(again.records.len(), 1);
        }
    }

    #[test]
    fn corrupt_body_is_a_typed_error() {
        let store = mem();
        let mut d = Durability::new(Box::new(store.clone()), DurabilityConfig::default());
        d.log_append(&[1.0, 2.0, 3.0]).unwrap();
        d.log_append(&[4.0, 5.0, 6.0]).unwrap();
        // Flip a byte inside the *first* record's body: a complete frame
        // with a bad checksum is corruption, never a torn tail.
        store.flip_byte(WAL, 14);
        let err = load(&mut store.clone()).unwrap_err();
        assert!(matches!(err, WalError::Corrupt(_)), "{err:?}");
    }

    #[test]
    fn sequence_gap_is_corruption() {
        let store = mem();
        let mut a = Durability::new(Box::new(store.clone()), DurabilityConfig::default());
        a.log_append(&[1.0]).unwrap();
        // Forge a second durability whose seq skips ahead.
        let mut b = Durability::resume(
            Box::new(store.clone()),
            DurabilityConfig::default(),
            5,
            0,
        );
        b.log_append(&[2.0]).unwrap();
        let err = load(&mut store.clone()).unwrap_err();
        assert!(matches!(err, WalError::Corrupt(_)), "{err:?}");
    }

    #[test]
    fn checkpoint_frame_round_trip_and_corruption() {
        let store = mem();
        let payload = vec![9u8, 8, 7, 6, 5];
        let mut d = Durability::new(Box::new(store.clone()), DurabilityConfig::default());
        d.log_append(&[1.0]).unwrap();
        d.write_checkpoint(&payload).unwrap();
        // Checkpoint resets the WAL; seq keeps counting.
        assert_eq!(store.len(WAL), 0);
        d.log_append(&[2.0]).unwrap();
        assert_eq!(d.next_seq(), 2);

        let loaded = load(&mut store.clone()).unwrap();
        assert_eq!(loaded.checkpoint.as_deref(), Some(&payload[..]));
        assert_eq!(loaded.records.len(), 1);
        assert_eq!(loaded.records[0].seq, 1);

        // Any flipped checkpoint byte is Corrupt (magic, len, payload, sum).
        let blob = store.raw(CHECKPOINT).unwrap();
        for idx in 0..blob.len() {
            let s = mem();
            s.set_raw(CHECKPOINT, blob.clone());
            s.flip_byte(CHECKPOINT, idx);
            let err = load(&mut s.clone());
            assert!(
                matches!(err, Err(WalError::Corrupt(_))),
                "byte {idx}: {err:?}"
            );
        }
        // A short-read checkpoint is Corrupt too, never truncated.
        for cap in 0..blob.len() {
            let s = mem();
            s.set_raw(CHECKPOINT, blob[..cap].to_vec());
            if cap == 0 {
                // Zero bytes parses as "blob exists but has no header".
                let err = load(&mut s.clone());
                assert!(matches!(err, Err(WalError::Corrupt(_))), "cap 0: {err:?}");
                continue;
            }
            let err = load(&mut s.clone());
            assert!(matches!(err, Err(WalError::Corrupt(_))), "cap {cap}: {err:?}");
        }
    }

    #[test]
    fn fault_store_budget_drops_and_torn_writes() {
        // Budget 1: the first append lands, the second vanishes.
        let base = mem();
        let faulty = FaultStore::new(Box::new(base.clone())).fail_after(1);
        let mut d = Durability::new(Box::new(faulty), DurabilityConfig::default());
        d.log_append(&[1.0]).unwrap();
        d.log_append(&[2.0]).unwrap(); // silently dropped
        let loaded = load(&mut base.clone()).unwrap();
        assert_eq!(loaded.records.len(), 1);
        assert_eq!(loaded.torn_tail_truncations, 0);

        // Torn tail: the over-budget append lands a 5-byte prefix.
        let base = mem();
        let faulty = FaultStore::new(Box::new(base.clone()))
            .fail_after(1)
            .with_torn_tail(5);
        let mut d = Durability::new(Box::new(faulty), DurabilityConfig::default());
        d.log_append(&[1.0]).unwrap();
        d.log_append(&[2.0]).unwrap();
        let loaded = load(&mut base.clone()).unwrap();
        assert_eq!(loaded.records.len(), 1);
        assert_eq!(loaded.torn_tail_truncations, 1);

        // Error mode: the drop is reported as Io.
        let base = mem();
        let faulty = FaultStore::new(Box::new(base.clone()))
            .fail_after(0)
            .with_error_on_fault();
        let mut d = Durability::new(Box::new(faulty), DurabilityConfig::default());
        let err = d.log_append(&[1.0]).unwrap_err();
        assert!(matches!(err, WalError::Io(_)));
    }

    #[test]
    fn file_store_round_trip() {
        let dir = std::env::temp_dir().join(format!(
            "ss_wal_unit_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let store = FileStore::open(&dir).unwrap();
            let mut d = Durability::new(Box::new(store), DurabilityConfig::default());
            d.log_append(&[1.5, -2.5]).unwrap();
            d.write_checkpoint(b"payload").unwrap();
            d.log_compact(2, &[0, 1]).unwrap();
        }
        {
            let mut store = FileStore::open(&dir).unwrap();
            let loaded = load(&mut store).unwrap();
            assert_eq!(loaded.checkpoint.as_deref(), Some(&b"payload"[..]));
            assert_eq!(loaded.records.len(), 1);
            assert_eq!(
                loaded.records[0].kind,
                RecordKind::Compact {
                    rounds: 2,
                    kept: vec![0, 1]
                }
            );
            assert_eq!(loaded.records[0].seq, 1);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Pass-through store that counts `flush` calls on the WAL blob.
    struct FlushCounter {
        inner: MemStore,
        flushes: Arc<AtomicU64>,
    }

    impl DurableStore for FlushCounter {
        fn read_all(&mut self, name: &str) -> Result<Option<Vec<u8>>, WalError> {
            self.inner.read_all(name)
        }
        fn append(&mut self, name: &str, bytes: &[u8]) -> Result<(), WalError> {
            self.inner.append(name, bytes)
        }
        fn write_atomic(&mut self, name: &str, bytes: &[u8]) -> Result<(), WalError> {
            self.inner.write_atomic(name, bytes)
        }
        fn truncate(&mut self, name: &str, len: u64) -> Result<(), WalError> {
            self.inner.truncate(name, len)
        }
        fn flush(&mut self, name: &str) -> Result<(), WalError> {
            if name == WAL {
                self.flushes.fetch_add(1, Ordering::SeqCst);
            }
            self.inner.flush(name)
        }
    }

    #[test]
    fn group_commit_batches_flushes_and_close_forces_one() {
        let flushes = Arc::new(AtomicU64::new(0));
        let store = FlushCounter { inner: mem(), flushes: Arc::clone(&flushes) };
        let cfg = DurabilityConfig::default()
            .with_checkpoint_interval(0)
            .with_flush_policy(FlushPolicy::EveryN(4));
        let mut d = Durability::new(Box::new(store), cfg);
        for i in 0..10 {
            d.log_append(&[i as f32]).unwrap();
        }
        // 10 appends at N=4 → flushes after records 4 and 8 only
        assert_eq!(flushes.load(Ordering::SeqCst), 2);
        // close flushes the 2-record remainder (close record included)
        d.log_close().unwrap();
        assert_eq!(flushes.load(Ordering::SeqCst), 3);
        // every record is on the store regardless of flush cadence
        let mut store = d.into_store();
        let loaded = load(&mut store).unwrap();
        assert_eq!(loaded.records.len(), 11);
    }

    #[test]
    fn every_record_policy_flushes_each_append() {
        let flushes = Arc::new(AtomicU64::new(0));
        let store = FlushCounter { inner: mem(), flushes: Arc::clone(&flushes) };
        let cfg = DurabilityConfig::default().with_checkpoint_interval(0);
        let mut d = Durability::new(Box::new(store), cfg);
        for i in 0..5 {
            d.log_append(&[i as f32]).unwrap();
        }
        assert_eq!(flushes.load(Ordering::SeqCst), 5);
    }
}
