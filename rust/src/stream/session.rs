//! Append-only streaming sessions: the arena SS loop fed from a live
//! stream instead of a fully materialized ground set.
//!
//! A [`StreamSession`] accepts batches of feature rows and maintains a
//! bounded retained core `V′` with a two-stage policy:
//!
//! 1. **Sieve hand-off** — an optional incremental
//!    [`SieveFilter`](super::SieveFilter) screens every arrival *before*
//!    its storage is admitted: only elements some threshold's candidate
//!    set wants enter the candidate buffer at all (Badanidiyuru et al.'s
//!    grid, reused unchanged from [`sieve_streaming`]).
//! 2. **Windowed re-sparsification** — when the buffer crosses the
//!    configured high-water mark, the existing `RoundScratch`-arena SS
//!    loop ([`sparsify_candidates`]) runs over `retained ∪ buffer` and
//!    shrinks the live set back to `O(log² n)`; evicted elements' feature
//!    rows (and, for facility location, similarity rows/columns) are
//!    **compacted away** through the objectives'
//!    [`retain_elements`](crate::submodular::SubmodularFn::retain_elements)
//!    capability, with the [`IdRemap`] spine keeping external ids stable
//!    across any number of evictions.
//!
//! Snapshots run the batched [`MaximizerEngine`] over the live set: the
//! stochastic-greedy route for cheap intermediate summaries ("Lazier Than
//! Lazy Greedy" justifies the stochastic refresh between
//! re-sparsifications), lazy greedy for final answers. They come in two
//! shapes sharing one compute path ([bit-identical results]):
//!
//! * [`snapshot_summary`](StreamSession::snapshot_summary) — in place,
//!   over the live storage, for callers that own the session;
//! * [`snapshot_core`](StreamSession::snapshot_core) — **copy-on-snapshot**:
//!   clone the bounded retained core (storage + the remap's external-id
//!   view) inside a short borrow, hand back a self-contained
//!   [`SnapshotCore`] whose [`run`](SnapshotCore::run) executes anywhere —
//!   the service runs it as a worker-pool job while appends keep landing
//!   on the session. The facility-location similarity build (dense
//!   `O(m²·d)` below the store crossover, sparse top-t above it) happens
//!   inside `run`, *not* under the borrow.
//!
//! Facility-location sessions above the dense crossover keep a
//! [`SparseSimStore`](crate::submodular::SparseSimStore)-backed objective
//! **live across the whole session**: appends grow it by row-border
//! insertion (`O(live·d)` per admitted row, metered as
//! `neighbor_updates`), re-sparsifications compact its neighbor lists in
//! place, and the windowed SS backend is parked and resumed between
//! windows instead of rebuilt — deleting both halves of the old
//! per-window `O(m²·d)` rebuild.
//!
//! **Batch equivalence.** A session whose window covers the entire stream
//! (`high_water = usize::MAX`) with the admission filter disabled is
//! *bit-identical* to the batch pipeline: appending rows one by one grows
//! the objective with the exact accumulation order of fresh construction,
//! and the final snapshot runs the same `sparsify → lazy_greedy` pair as
//! [`ss_then_greedy`](crate::algorithms::ss_then_greedy) with the same
//! seed. `rust/tests/stream_equivalence.rs` pins this across objectives,
//! shard counts and seeds.
//!
//! **Steady-state appends allocate nothing** on the CPU route once
//! capacity is reserved ([`StreamSession::reserve`]): id assignment, row
//! push, filter gain/commit and metric bumps all run in preallocated or
//! atomic storage — asserted by the counting allocator in
//! `rust/tests/alloc_steady_state.rs`. The allocator is only touched by
//! re-sparsifications, sieve re-grids and snapshots (and, on durable
//! sessions only, the write-ahead log's record framing).
//!
//! **Durability.** A session opened with
//! [`open_durable`](StreamSession::open_durable) logs every batch to a
//! write-ahead log *before* applying it and periodically writes a full
//! checkpoint (the [`SnapshotCore`] clone extended with the remap, filter
//! and counter state — see `stream::checkpoint`), so
//! [`recover`](StreamSession::recover) after a crash rebuilds a session
//! **bit-identical** to the uninterrupted one: replay re-runs the exact
//! deterministic append path over the durable batch suffix. Torn WAL
//! tails are truncated; corrupt records or checkpoints *quarantine* the
//! session — every subsequent mutating call reports a typed
//! [`ServiceError::Rejected`] instead of panicking or silently diverging
//! from the durable state. Pinned by `rust/tests/stream_recovery.rs`,
//! which kills the store at every write between two appends.
//!
//! [bit-identical results]: SnapshotCore::run
//! [`sieve_streaming`]: crate::algorithms::sieve_streaming
//! [`sparsify_candidates`]: crate::algorithms::sparsify_candidates
//! [`MaximizerEngine`]: crate::algorithms::MaximizerEngine

use std::collections::VecDeque;
use std::sync::Arc;

use crate::algorithms::{
    sparsify_traced, GainRoute, Interrupt, MaximizerEngine, Solution, SsParams,
};
use crate::coordinator::job::ServiceError;
use crate::coordinator::{Compute, Metrics, ShardedBackend};
use crate::trace::{EventKind, Tracer};
use crate::submodular::{
    BatchedDivergence, BuildStrategy, FacilityLocation, FeatureBased, ObjectiveSpec,
    SparseSimStore, SubmodularFn,
};
use crate::util::pool::ThreadPool;
use crate::util::stats::Timer;
use crate::util::vecmath::{add_into, FeatureMatrix};

use crate::algorithms::sieve_filter::{SieveFilter, SieveParams, SieveSet};

use super::checkpoint::{CheckpointState, FilterPayload, SievePayload, SparseParts, StorePayload};
use super::remap::IdRemap;
use super::wal::{self, Durability, DurabilityConfig, DurableStore, RecordKind};

/// Session configuration. Construct with [`StreamConfig::new`] and refine
/// with the builder methods.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// summary budget k
    pub k: usize,
    /// SS parameters for windowed re-sparsification *and* the final
    /// snapshot (per-window seeds are derived from `ss.seed` so windows
    /// draw independent probes; window 0 uses `ss.seed` itself, which is
    /// what makes the full-window session bit-match the batch pipeline).
    /// Set `ss.min_keep ≥ k` when budgets are large relative to `log² n`.
    pub ss: SsParams,
    /// Candidate-buffer high-water mark: an admitted arrival that leaves
    /// more than this many unsparsified elements triggers a windowed
    /// re-sparsification. `usize::MAX` = full window (never re-sparsify
    /// until the final snapshot).
    pub high_water: usize,
    /// Hard cap on live (retained + buffered) elements — the per-session
    /// backpressure point: an append batch that cannot fit even after a
    /// forced re-sparsification is shed with
    /// [`ServiceError::QueueFull`]. 0 = uncapped.
    pub max_live: usize,
    /// Sieve admission filter ([`ObjectiveSpec::Features`] only).
    /// `None` = admit every arrival.
    pub admission: Option<SieveParams>,
    /// Shard-count override for the windowed SS backend (0 = default).
    pub shards: usize,
    /// ε for the stochastic-greedy intermediate-snapshot route.
    pub intermediate_eps: f64,
    /// Expected stream length: capacity reserved at construction so
    /// steady-state appends start allocation-free (the only way to
    /// pre-reserve a service-opened stream; [`StreamSession::reserve`]
    /// remains available on directly-owned sessions). 0 = grow on demand.
    pub reserve_hint: usize,
}

impl StreamConfig {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            ss: SsParams::default(),
            high_water: usize::MAX,
            max_live: 0,
            admission: None,
            shards: 0,
            intermediate_eps: 0.2,
            reserve_hint: 0,
        }
    }

    pub fn with_ss(mut self, ss: SsParams) -> Self {
        self.ss = ss;
        self
    }

    pub fn with_high_water(mut self, hw: usize) -> Self {
        self.high_water = hw;
        self
    }

    pub fn with_max_live(mut self, cap: usize) -> Self {
        self.max_live = cap;
        self
    }

    pub fn with_admission(mut self, params: SieveParams) -> Self {
        self.admission = Some(params);
        self
    }

    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    pub fn with_reserve(mut self, expected_stream_len: usize) -> Self {
        self.reserve_hint = expected_stream_len;
        self
    }
}

/// How a snapshot trades cost for exactness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SnapshotMode {
    /// Stochastic greedy over the live set — the cheap between-windows
    /// refresh (Mirzasoleiman et al.), no SS pass.
    Intermediate,
    /// Full `sparsify → lazy greedy` over the live set — the batch
    /// pipeline's exact configuration.
    Final,
}

/// Outcome of one [`StreamSession::append`] batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamAppend {
    /// External id assigned to the batch's first element (ids are
    /// sequential, so element `i` of the batch got `first_ext + i`).
    pub first_ext: usize,
    /// Elements appended (== batch size).
    pub appended: usize,
    /// Elements the admission filter let into the candidate buffer.
    pub admitted: usize,
    /// Windowed re-sparsifications triggered by this batch.
    pub resparsifies: usize,
    /// Elements evicted by those re-sparsifications.
    pub evicted: usize,
    /// SS rounds those re-sparsifications ran.
    pub ss_rounds: usize,
    /// Wall time spent inside those re-sparsifications (the SS pass +
    /// compaction only — append/filter work excluded), for latency
    /// attribution without external instrumentation.
    pub resparsify_s: f64,
}

/// A summary snapshot, in stable external ids.
#[derive(Clone, Debug)]
pub struct StreamSummary {
    /// Selected elements (external ids), in selection order.
    pub summary: Vec<usize>,
    pub value: f64,
    /// Live (retained + buffered) elements at snapshot time.
    pub live: usize,
    pub retained: usize,
    pub buffered: usize,
    /// SS rounds the snapshot itself ran (0 for [`SnapshotMode::Intermediate`]).
    pub ss_rounds: usize,
}

/// Lifetime accounting for a session. `PartialEq`/`Eq` (all fields are
/// integers) so recovery tests can compare whole-session accounting at
/// once.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StreamStats {
    pub appends: u64,
    pub admitted: u64,
    pub evicted: u64,
    /// Completed windowed re-sparsifications.
    pub windows: u64,
    /// Total SS rounds across them.
    pub ss_rounds: u64,
    pub live: usize,
    pub retained: usize,
    pub buffered: usize,
    /// Total external ids assigned.
    pub assigned: usize,
    /// High-water mark of elements resident in the admission filter's
    /// threshold sets (0 when the filter is disabled).
    pub filter_peak_resident: usize,
}

/// Per-threshold candidate-set state for the streaming admission filter:
/// a coverage vector is all the feature-based objective needs to price a
/// row's marginal gain, so rejected elements never get storage anywhere.
struct CovSieve {
    cov: Vec<f32>,
    value: f64,
    len: usize,
}

impl SieveSet for CovSieve {
    fn len(&self) -> usize {
        self.len
    }
    fn value(&self) -> f64 {
        self.value
    }
}

/// Live element storage. The first `retained_len` internal indices are the
/// retained core; everything after is the unsparsified candidate buffer.
enum LiveStore {
    /// The objective *is* the storage: grown row by row, compacted in
    /// place — never rebuilt.
    Features(Arc<FeatureBased>),
    /// Raw rows plus a lazily built similarity objective. A sparse-store
    /// objective stays valid across the whole session lifecycle: appends
    /// grow it by row-border insertion and re-sparsifications compact it
    /// in place, so it is built from scratch at most once. A dense
    /// (small-n) objective is invalidated by appends and rebuilt lazily —
    /// the rebuild rides the `crossover` auto-selection, so a session that
    /// outgrows the dense regime comes back sparse.
    Facility {
        feats: FeatureMatrix,
        cached: Option<Arc<FacilityLocation>>,
        /// ground-set size below which the store is dense
        /// ([`ObjectiveSpec::facility_store_params`])
        crossover: usize,
        /// explicit top-t override (`None` = auto `O(log n)`)
        t: Option<usize>,
        /// neighbor-build strategy above the crossover (exact all-pairs,
        /// forced LSH geometry, or size-gated auto) — threaded into every
        /// build site so batch, snapshot and recovery stores agree
        build: BuildStrategy,
    },
}

/// A compaction decision parsed back out of the WAL, queued for the
/// batch replay that triggered it (recovery only; empty in live use).
struct ReplayCompact {
    rounds: usize,
    kept: Vec<usize>,
}

/// Receipt of one completed checkpoint.
#[derive(Clone, Copy, Debug)]
pub struct CheckpointInfo {
    /// WAL sequence the checkpoint covers up to (exclusive).
    pub seq: u64,
    /// Checkpoint blob size on the durable store, bytes.
    pub bytes: usize,
}

/// What recovery found and did.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecoveryReport {
    /// WAL sequence the checkpoint covered up to (exclusive).
    pub checkpoint_seq: u64,
    /// WAL records replayed on top of the checkpoint — bounded by the
    /// configured checkpoint interval (plus in-flight compaction/close
    /// records of the final batches).
    pub replayed_records: u64,
    /// 1 when a torn WAL tail was truncated away, else 0.
    pub torn_tail_truncations: u64,
}

pub struct StreamSession {
    cfg: StreamConfig,
    d: usize,
    store: LiveStore,
    remap: IdRemap,
    /// live internal indices `[0, retained_len)` have survived a
    /// re-sparsification; `[retained_len, live)` are buffered arrivals
    retained_len: usize,
    buffer_len: usize,
    filter: Option<SieveFilter<CovSieve>>,
    pool: Arc<ThreadPool>,
    metrics: Arc<Metrics>,
    /// The windowed SS backend, parked between uses so re-sparsifications
    /// and snapshots resume it (keeping its pool wiring, shard count and
    /// warmed scratch) instead of constructing a fresh one per window —
    /// only taken when the objective supports retain (both live stores
    /// do); parking drops the objective handle so storage compaction and
    /// appends keep exclusive access to theirs.
    parked: Option<crate::coordinator::ParkedBackend>,
    windows: u64,
    ss_rounds: u64,
    appends: u64,
    admitted: u64,
    evicted: u64,
    closed: bool,
    /// Mutation epoch: bumped whenever the live set changes (an admitted
    /// element or a compaction). [`snapshot_core`](Self::snapshot_core)
    /// reuses `core_cache` while the epoch is unchanged, so quiet streams
    /// pay zero clones per snapshot/checkpoint.
    epoch: u64,
    core_cache: Option<(u64, Arc<SnapshotCore>)>,
    /// Deep core clones actually performed (the no-clone counter the
    /// epoch-cache test asserts on).
    core_builds: u64,
    /// WAL + checkpoint machinery; `None` on plain in-memory sessions
    /// (the steady-state append hook is then a single branch).
    durability: Option<Durability>,
    /// Recovery replay only: compaction decisions logged by the batch
    /// currently being replayed, consumed by [`resparsify`](Self::resparsify)
    /// in place of re-running SS. Always empty during live operation.
    pending_compacts: VecDeque<ReplayCompact>,
}

impl StreamSession {
    /// A fresh session over `d`-dimensional rows. `pool` carries the
    /// windowed SS shards; `metrics` receives both the stream counters
    /// (`stream_appends`, `stream_admitted`, `resparsify_rounds`,
    /// `evicted_elements`) and the per-window backend counters
    /// (`divergence_evals`, `gain_evals`, …) — hand each session its own
    /// [`Metrics`] (and [`Metrics::reset`] it between windows if desired)
    /// to keep long-lived sessions from conflating scopes. An unservable
    /// configuration reports [`ServiceError::Rejected`].
    pub fn new(
        objective: ObjectiveSpec,
        d: usize,
        cfg: StreamConfig,
        pool: Arc<ThreadPool>,
        metrics: Arc<Metrics>,
    ) -> Result<Self, ServiceError> {
        let reject = |reason: &str| ServiceError::Rejected { reason: reason.into() };
        if d == 0 {
            return Err(reject("stream sessions need d >= 1"));
        }
        if cfg.k == 0 {
            return Err(reject("stream sessions need a budget k >= 1"));
        }
        if !(cfg.intermediate_eps > 0.0 && cfg.intermediate_eps < 1.0) {
            return Err(reject("intermediate_eps must be in (0, 1)"));
        }
        // Shape checks that used to fail far downstream (a high-water
        // window smaller than the budget starves every snapshot; a live
        // cap below the window sheds every batch that tries to fill it) —
        // reported at open time as typed rejections instead.
        if cfg.high_water < cfg.k {
            return Err(reject("high_water must be at least the budget k"));
        }
        if cfg.max_live > 0 && cfg.max_live < cfg.high_water {
            return Err(reject("max_live must be at least high_water (or 0 = uncapped)"));
        }
        let filter = match (&cfg.admission, objective) {
            (None, _) => None,
            (Some(p), ObjectiveSpec::Features(_)) => Some(SieveFilter::new(cfg.k, p)),
            (Some(_), _) => {
                return Err(reject(
                    "sieve admission needs per-row gains; facility location's depend on \
                     the whole ground set — open the session without a filter",
                ));
            }
        };
        let store = match objective {
            ObjectiveSpec::Features(g) => {
                LiveStore::Features(Arc::new(FeatureBased::new(FeatureMatrix::zeros(0, d), g)))
            }
            _ => {
                let (crossover, t, build) = objective
                    .facility_store_params()
                    .expect("non-feature specs are facility-location shaped");
                LiveStore::Facility {
                    feats: FeatureMatrix::zeros(0, d),
                    cached: None,
                    crossover,
                    t,
                    build,
                }
            }
        };
        let mut session = Self {
            cfg,
            d,
            store,
            remap: IdRemap::new(),
            retained_len: 0,
            buffer_len: 0,
            filter,
            pool,
            metrics,
            parked: None,
            windows: 0,
            ss_rounds: 0,
            appends: 0,
            admitted: 0,
            evicted: 0,
            closed: false,
            epoch: 0,
            core_cache: None,
            core_builds: 0,
            durability: None,
            pending_compacts: VecDeque::new(),
        };
        let hint = session.cfg.reserve_hint;
        if hint > 0 {
            session.reserve(hint);
        }
        Ok(session)
    }

    /// A fresh **durable** session: [`new`](Self::new) plus a write-ahead
    /// log on `store` and an immediate initial checkpoint (so recovery
    /// always finds the session's configuration, even before the first
    /// append). From here on every batch is logged before it is applied
    /// and a checkpoint is written every `dcfg.checkpoint_interval`
    /// records.
    pub fn open_durable(
        objective: ObjectiveSpec,
        d: usize,
        cfg: StreamConfig,
        pool: Arc<ThreadPool>,
        metrics: Arc<Metrics>,
        store: Box<dyn DurableStore>,
        dcfg: DurabilityConfig,
    ) -> Result<Self, ServiceError> {
        let mut session = Self::new(objective, d, cfg, pool, metrics)?;
        session.durability = Some(Durability::new(store, dcfg));
        session.checkpoint_now()?;
        Ok(session)
    }

    /// Rebuild a session from its durable store: verify + decode the
    /// checkpoint, truncate a torn WAL tail if the last crash left one,
    /// then replay the WAL suffix through the ordinary (deterministic)
    /// append path — the recovered session is **bit-identical** to the
    /// uninterrupted one. Corrupt bytes surface as
    /// [`ServiceError::Rejected`]; nothing here panics on bad input.
    pub fn recover(
        pool: Arc<ThreadPool>,
        metrics: Arc<Metrics>,
        store: Box<dyn DurableStore>,
        dcfg: DurabilityConfig,
    ) -> Result<Self, ServiceError> {
        Self::recover_with_report(pool, metrics, store, dcfg).map(|(s, _)| s)
    }

    /// [`recover`](Self::recover), also returning what was found/replayed.
    pub fn recover_with_report(
        pool: Arc<ThreadPool>,
        metrics: Arc<Metrics>,
        mut store: Box<dyn DurableStore>,
        dcfg: DurabilityConfig,
    ) -> Result<(Self, RecoveryReport), ServiceError> {
        let reject = |reason: String| ServiceError::Rejected { reason };
        let loaded = wal::load(store.as_mut())
            .map_err(|e| reject(format!("recovery failed: {e}")))?;
        let payload = loaded
            .checkpoint
            .ok_or_else(|| reject("recovery failed: no checkpoint in the durable store".into()))?;
        let state = super::checkpoint::decode(&payload)
            .map_err(|e| reject(format!("recovery failed: {e}")))?;
        let wal_seq = state.wal_seq;
        let mut session = Self::from_checkpoint_state(state, pool, Arc::clone(&metrics))?;

        // The tail: records the checkpoint does not cover. Records below
        // `wal_seq` are leftovers of a crash between checkpoint-write and
        // WAL-truncate — already folded into the checkpoint, skipped. The
        // parser enforced in-file seq contiguity, so one boundary check
        // rules out a gap.
        let records: Vec<wal::WalRecord> =
            loaded.records.into_iter().filter(|r| r.seq >= wal_seq).collect();
        if let Some(first) = records.first() {
            if first.seq != wal_seq {
                return Err(reject(format!(
                    "recovery failed: WAL resumes at seq {} but the checkpoint covers only below {}",
                    first.seq, wal_seq
                )));
            }
        }
        let replayed = records.len() as u64;
        let next_seq = records.last().map_or(wal_seq, |r| r.seq + 1);

        let mut i = 0usize;
        while i < records.len() {
            match &records[i].kind {
                RecordKind::Append(rows) => {
                    // queue the compaction decisions this batch logged, so
                    // replay applies them instead of re-running SS
                    let mut j = i + 1;
                    while j < records.len() {
                        match &records[j].kind {
                            RecordKind::Compact { rounds, kept } => {
                                session.pending_compacts.push_back(ReplayCompact {
                                    rounds: *rounds as usize,
                                    kept: kept.iter().map(|&k| k as usize).collect(),
                                });
                                j += 1;
                            }
                            _ => break,
                        }
                    }
                    if rows.len() % session.d != 0 {
                        return Err(reject(
                            "recovery failed: WAL batch width disagrees with the session's d"
                                .into(),
                        ));
                    }
                    let nonneg = matches!(session.store, LiveStore::Features(_));
                    if !rows.iter().all(|x| x.is_finite() && (!nonneg || *x >= 0.0)) {
                        return Err(reject(
                            "recovery failed: WAL batch holds out-of-domain features".into(),
                        ));
                    }
                    // a QueueFull here re-sheds exactly the batch the
                    // original session shed (same state, same cap) — the
                    // shed *is* part of the deterministic history
                    let _ = session.append_prevalidated(rows);
                    if !session.pending_compacts.is_empty() {
                        return Err(reject(
                            "recovery failed: WAL compaction records diverge from replay".into(),
                        ));
                    }
                    i = j;
                }
                RecordKind::Compact { .. } => {
                    return Err(reject(
                        "recovery failed: stray compaction record without a preceding append"
                            .into(),
                    ));
                }
                RecordKind::Close => {
                    session.close();
                    i += 1;
                }
            }
        }

        session.durability = Some(Durability::resume(store, dcfg, next_seq, replayed));
        metrics.add(&metrics.counters.recoveries, 1);
        if loaded.torn_tail_truncations > 0 {
            metrics.add(
                &metrics.counters.torn_tail_truncations,
                loaded.torn_tail_truncations,
            );
        }
        let report = RecoveryReport {
            checkpoint_seq: wal_seq,
            replayed_records: replayed,
            torn_tail_truncations: loaded.torn_tail_truncations,
        };
        Ok((session, report))
    }

    /// Reserve capacity for `additional` further appends so the
    /// steady-state [`append`](Self::append) path never touches the
    /// allocator (the invariant `rust/tests/alloc_steady_state.rs`
    /// enforces).
    pub fn reserve(&mut self, additional: usize) {
        self.remap.reserve(additional);
        match &mut self.store {
            LiveStore::Features(fb) => Arc::get_mut(fb)
                .expect("objective handle leaked outside the session")
                .reserve_elements(additional),
            LiveStore::Facility { feats, .. } => feats.reserve_rows(additional),
        }
    }

    /// Append a batch of rows (row-major, `len % d == 0`). Every element
    /// gets a stable external id; the admission filter (if any) decides
    /// which enter the candidate buffer; crossing the high-water mark
    /// triggers windowed re-sparsification inline. Backpressure: a batch
    /// that cannot fit under `max_live` even after a forced
    /// re-sparsification is rejected whole with
    /// [`ServiceError::QueueFull`]; a closed session reports
    /// [`ServiceError::ServiceDown`].
    pub fn append(&mut self, rows: &[f32]) -> Result<StreamAppend, ServiceError<()>> {
        Self::validate_batch(rows, self.d, matches!(self.store, LiveStore::Features(_)));
        self.append_prevalidated(rows)
    }

    /// Whole-batch input validation — alignment, finiteness, and (for
    /// feature-based coverage, which needs non-negative mass)
    /// non-negativity; facility-location sessions accept signed
    /// embeddings, whose cosines `from_features` clamps exactly like the
    /// batch pipeline. Runs **before any mutation**, so a bad value can
    /// never leave a session half-appended, reach the admission filter's
    /// NaN-intolerant comparisons, or (in release) poison coverage sums.
    /// Panics: invalid input is a caller bug. The service calls this
    /// before taking the session lock and then uses
    /// [`append_prevalidated`](Self::append_prevalidated), so the O(n·d)
    /// scan runs once and outside the critical section.
    pub(crate) fn validate_batch(rows: &[f32], d: usize, nonneg: bool) {
        assert_eq!(rows.len() % d, 0, "append batch must be row-major d-wide");
        assert!(rows.iter().all(|x| x.is_finite()), "append batch must contain finite features");
        if nonneg {
            assert!(
                rows.iter().all(|&x| x >= 0.0),
                "feature-based sessions need non-negative features"
            );
        }
    }

    /// [`append`](Self::append) without the input scan — for callers that
    /// already ran [`validate_batch`](Self::validate_batch) on this exact
    /// batch (the service does, pre-lock).
    pub(crate) fn append_prevalidated(
        &mut self,
        rows: &[f32],
    ) -> Result<StreamAppend, ServiceError<()>> {
        if self.closed {
            return Err(ServiceError::ServiceDown);
        }
        if let Some(du) = self.durability.as_ref() {
            if let Some(reason) = du.quarantined() {
                return Err(ServiceError::Rejected {
                    reason: format!("session quarantined: {reason}"),
                });
            }
        }
        // Log-before-apply: the whole raw batch goes to the WAL (rejected
        // rows still advance sieve + remap state, and a shed batch is part
        // of the deterministic history — replay re-sheds it) before any
        // in-memory mutation, so every durable WAL prefix corresponds to a
        // reachable session state. An I/O failure quarantines: continuing
        // un-logged would silently diverge from what recovery can rebuild.
        if let Some(du) = self.durability.as_mut() {
            let span = self.metrics.tracer().start();
            let wal_seq = du.next_seq();
            if let Err(e) = du.log_append(rows) {
                let reason = e.to_string();
                du.quarantine(reason.clone());
                self.metrics.tracer().record_now(EventKind::Quarantine, 0, 0, 0, 0);
                return Err(ServiceError::Rejected { reason });
            }
            self.metrics.add(&self.metrics.counters.wal_appends, 1);
            self.metrics.tracer().record_since(
                EventKind::WalFlush,
                span,
                (rows.len() / self.d) as u64,
                wal_seq,
                0,
                0,
            );
        }
        debug_assert_eq!(rows.len() % self.d, 0);
        let batch_n = rows.len() / self.d;
        let mut out = StreamAppend { first_ext: self.remap.assigned(), ..Default::default() };
        if self.cfg.max_live > 0 && self.live() + batch_n > self.cfg.max_live {
            // a batch bigger than the cap itself can never fit — shed it
            // before burning (and eroding the retained core with) a forced
            // re-sparsification that cannot help
            if batch_n > self.cfg.max_live {
                return Err(ServiceError::QueueFull(()));
            }
            // worst case every element is admitted: shed unless a forced
            // re-sparsification frees enough headroom
            if self.buffer_len > 0 {
                self.resparsify_into(&mut out);
            }
            if self.live() + batch_n > self.cfg.max_live {
                return Err(ServiceError::QueueFull(()));
            }
        }
        let mut neighbor_updates = 0u64;
        for row in rows.chunks_exact(self.d) {
            out.appended += 1;
            if !self.admit(row) {
                self.remap.reject();
                continue;
            }
            let (_ext, int) = self.remap.admit();
            match &mut self.store {
                LiveStore::Features(fb) => {
                    let fb = Arc::get_mut(fb).expect("objective handle leaked outside the session");
                    debug_assert_eq!(fb.n(), int);
                    fb.push_element(row);
                }
                LiveStore::Facility { feats, cached, .. } => {
                    debug_assert_eq!(feats.n(), int);
                    feats.push_row(row);
                    // a sparse store grows by row-border insertion —
                    // O(live·d) for the new row, no rebuild; a dense
                    // store declines, dropping back to the lazy-rebuild
                    // path (which re-selects sparse once the live set
                    // outgrows the crossover)
                    if let Some(mut fl) = cached.take() {
                        if let Some(updates) =
                            Arc::make_mut(&mut fl).append_row_from_features(feats)
                        {
                            neighbor_updates += updates;
                            *cached = Some(fl);
                        }
                    }
                }
            }
            self.buffer_len += 1;
            out.admitted += 1;
            if self.buffer_len > self.cfg.high_water {
                self.resparsify_into(&mut out);
            }
        }
        if neighbor_updates > 0 {
            self.metrics.add(&self.metrics.counters.neighbor_updates, neighbor_updates);
        }
        // one RMW per counter per batch, not per element — the per-element
        // form costs two relaxed fetch_adds in the hot append loop
        self.appends += out.appended as u64;
        self.admitted += out.admitted as u64;
        self.metrics.add(&self.metrics.counters.stream_appends, out.appended as u64);
        self.metrics.add(&self.metrics.counters.stream_admitted, out.admitted as u64);
        if out.admitted > 0 {
            self.epoch = self.epoch.wrapping_add(1);
        }
        // Auto-checkpoint once the interval has elapsed. Failure inside
        // checkpoint_now quarantines on its own; the batch itself already
        // applied and logged fine, so its outcome stands.
        if self
            .durability
            .as_ref()
            .is_some_and(|du| du.quarantined().is_none() && du.checkpoint_due())
        {
            let _ = self.checkpoint_now();
        }
        Ok(out)
    }

    /// Run one windowed re-sparsification and fold its accounting (count,
    /// evictions, rounds, wall time) into an append outcome.
    fn resparsify_into(&mut self, out: &mut StreamAppend) {
        let t = Timer::new();
        let (ev, rounds) = self.resparsify();
        out.resparsify_s += t.elapsed_s();
        out.resparsifies += 1;
        out.evicted += ev;
        out.ss_rounds += rounds;
    }

    /// Sieve hand-off: screen one row before admitting its storage.
    fn admit(&mut self, row: &[f32]) -> bool {
        let Some(filter) = self.filter.as_mut() else { return true };
        let LiveStore::Features(fb) = &self.store else { unreachable!("validated in new()") };
        let g = fb.concave();
        let d = self.d;
        // row-form kernels shared with FeatureBased::singleton /
        // gain_over_cov, so filter pricing can never drift from the
        // objective bit-wise
        let sv = g.row_singleton(row);
        filter.observe(sv, || CovSieve { cov: vec![0.0; d], value: 0.0, len: 0 });
        filter.offer(
            |s| g.row_gain(&s.cov, row),
            |s, gain| {
                s.value += gain;
                add_into(&mut s.cov, row);
                s.len += 1;
            },
        )
    }

    /// Windowed re-sparsification: the arena SS loop over
    /// `retained ∪ buffer`, then compaction of storage and remap to the
    /// surviving core. Returns `(evicted, ss_rounds)`.
    fn resparsify(&mut self) -> (usize, usize) {
        let m = self.live();
        if m == 0 {
            self.buffer_len = 0;
            return (0, 0);
        }
        let span = self.metrics.tracer().start();
        // Recovery replay: the WAL recorded what this window decided, so
        // apply the logged verdict instead of re-running SS — a pure
        // optimization (the live pass below recomputes the identical kept
        // set from the identical state + seed), which also lets recovery
        // skip the most expensive part of replay. A record that fails the
        // shape checks is dropped and the live pass takes over.
        if let Some(rec) = self.pending_compacts.pop_front() {
            let valid = rec.kept.len() <= m
                && rec.kept.windows(2).all(|w| w[0] < w[1])
                && rec.kept.last().map_or(true, |&l| l < m);
            if valid {
                let evicted = self.apply_compaction(&rec.kept, rec.rounds);
                self.record_window(span, m, evicted, rec.rounds);
                return (evicted, rec.rounds);
            }
        }
        let obj = self.objective();
        let backend = self.resume_backend(&obj);
        let params = SsParams { seed: self.window_seed(), ..self.cfg.ss.clone() };
        // sparsify == sparsify_candidates over (0..backend.n()), and
        // backend.n() is exactly the live set here; the traced form records
        // one SsRound span per round on this stream's recorder ring
        let res = match sparsify_traced(&backend, &params, &mut || None, self.metrics.tracer()) {
            Ok(res) => res,
            Err(_) => unreachable!("a None-returning check can never interrupt"),
        };
        // park (not drop) the backend: its objective handle and singleton
        // precompute go away — compaction invalidates both — but the pool
        // wiring and scratch carry into the next window's resume
        self.parked = Some(backend.park());
        drop(obj); // release the Arc so compaction can take &mut
        // log the verdict before applying it, mirroring the append path;
        // the enclosing append already logged, so a failure here only
        // loses an optimization — quarantine still stops further writes
        if let Some(du) = self.durability.as_mut() {
            if du.quarantined().is_none() {
                if let Err(e) = du.log_compact(res.rounds, &res.kept) {
                    du.quarantine(e.to_string());
                    self.metrics.tracer().record_now(EventKind::Quarantine, 0, 0, 0, 0);
                }
            }
        }
        let evicted = self.apply_compaction(&res.kept, res.rounds);
        self.record_window(span, m, evicted, res.rounds);
        (evicted, res.rounds)
    }

    /// One [`EventKind::Window`] span per re-sparsification: payload
    /// `[live_before, retained, evicted, ss_rounds]` (replayed windows
    /// report the logged round count with `evicted` from the recorded
    /// verdict).
    fn record_window(&self, span: u64, live_before: usize, evicted: usize, rounds: usize) {
        self.metrics.tracer().record_since(
            EventKind::Window,
            span,
            live_before as u64,
            (live_before - evicted) as u64,
            evicted as u64,
            rounds as u64,
        );
    }

    /// Compact storage, remap and accounting to a surviving `kept` set
    /// (ascending internal indices) — the apply half of a window, shared
    /// by the live SS pass and WAL replay.
    fn apply_compaction(&mut self, kept: &[usize], rounds: usize) -> usize {
        let m = self.live();
        let evicted = m - kept.len();
        self.remap.compact(kept);
        match &mut self.store {
            LiveStore::Features(fb) => {
                let ok = Arc::get_mut(fb)
                    .expect("objective handle leaked outside the session")
                    .retain_elements(kept);
                debug_assert!(ok);
            }
            LiveStore::Facility { feats, cached, .. } => {
                feats.retain_rows(kept);
                // the compacted objective stays valid for an immediately
                // following snapshot — and, when sparse, for the appends
                // that grow it afterwards (neighbor lists are index-
                // rewritten in place, never rebuilt)
                if let Some(fl) = cached {
                    let ok = Arc::get_mut(fl)
                        .expect("objective handle leaked outside the session")
                        .retain_elements(kept);
                    debug_assert!(ok);
                }
            }
        }
        self.retained_len = kept.len();
        self.buffer_len = 0;
        self.windows += 1;
        self.ss_rounds += rounds as u64;
        self.evicted += evicted as u64;
        self.epoch = self.epoch.wrapping_add(1);
        self.metrics.add(&self.metrics.counters.resparsify_rounds, rounds as u64);
        self.metrics.add(&self.metrics.counters.evicted_elements, evicted as u64);
        evicted
    }

    /// Summarize the current live set **in place** (no storage clone).
    /// [`SnapshotMode::Final`] runs the exact batch pipeline
    /// (`sparsify → lazy greedy`, same window seed),
    /// [`SnapshotMode::Intermediate`] the cheap stochastic-greedy route.
    /// Read-only with respect to the live set: nothing is evicted.
    /// Bit-identical to [`snapshot_core`](Self::snapshot_core) +
    /// [`SnapshotCore::run`] on a quiesced session — both ride
    /// [`summarize_live`] over the same data, seed and backend shape.
    pub fn snapshot_summary(&mut self, mode: SnapshotMode) -> Result<StreamSummary, ServiceError> {
        if self.closed {
            return Err(ServiceError::ServiceDown);
        }
        let m = self.live();
        if m == 0 {
            return Ok(StreamSummary {
                summary: Vec::new(),
                value: 0.0,
                live: 0,
                retained: self.retained_len,
                buffered: self.buffer_len,
                ss_rounds: 0,
            });
        }
        let params = SsParams { seed: self.window_seed(), ..self.cfg.ss.clone() };
        let obj = self.objective();
        let backend = self.resume_backend(&obj);
        let (sol, ss_rounds) = match summarize_live(
            &obj,
            &backend,
            mode,
            self.cfg.k,
            self.cfg.intermediate_eps,
            &params,
            m,
            &mut || None,
            self.metrics.tracer(),
        ) {
            Ok(done) => done,
            Err(_) => unreachable!("a None-returning check can never interrupt"),
        };
        self.parked = Some(backend.park());
        Ok(StreamSummary {
            summary: sol.set.iter().map(|&i| self.remap.external(i)).collect(),
            value: sol.value,
            live: m,
            retained: self.retained_len,
            buffered: self.buffer_len,
            ss_rounds,
        })
    }

    /// **Copy-on-snapshot**: clone the bounded retained core into a
    /// self-contained [`SnapshotCore`] that can run the summary *without
    /// the session* — the job the service puts on its worker pool so a
    /// long Final snapshot no longer stalls the session's appends.
    ///
    /// Cost under the borrow: `O(m·d)` row clone plus `O(m)` id-view copy
    /// (`m` = live set, bounded by windowing at `O(log² n)` + buffer) —
    /// the facility-location `O(m²·d)` similarity build is deferred to
    /// [`SnapshotCore::run`]. The clone captures this window's seed, so
    /// the job's summary is bit-identical to what
    /// [`snapshot_summary`](Self::snapshot_summary) would have produced at
    /// the moment of the clone, regardless of appends that land while the
    /// job runs.
    ///
    /// **Quiet streams pay no clone at all**: the core is cached against
    /// the session's mutation epoch, so back-to-back snapshots (or
    /// checkpoints) with no intervening admitted element or compaction
    /// share one immutable `Arc` — [`core_builds`](Self::core_builds)
    /// counts the deep clones actually performed.
    pub fn snapshot_core(&mut self) -> Result<Arc<SnapshotCore>, ServiceError> {
        if self.closed {
            return Err(ServiceError::ServiceDown);
        }
        if let Some((epoch, core)) = &self.core_cache {
            if *epoch == self.epoch {
                return Ok(Arc::clone(core));
            }
        }
        let core = Arc::new(self.build_core());
        self.core_cache = Some((self.epoch, Arc::clone(&core)));
        self.core_builds += 1;
        Ok(core)
    }

    /// The deep clone behind [`snapshot_core`](Self::snapshot_core) —
    /// always into *fresh* `Arc`s, never sharing the session's live
    /// objective handles (appends take `Arc::get_mut` on those).
    fn build_core(&self) -> SnapshotCore {
        let store = match &self.store {
            LiveStore::Features(fb) => CoreStore::Features(Arc::new(fb.as_ref().clone())),
            LiveStore::Facility { feats, cached, crossover, t, build } => CoreStore::Facility {
                // rows are always captured (the checkpoint needs them even
                // when a built store rides along)
                feats: feats.clone(),
                // a live sparse store is cloned outright (`O(n·t)` — cheap
                // enough under the borrow, unlike the dense `O(m²·d)`
                // build): after evictions its incrementally-maintained
                // neighbor lists are *not* reproducible by a fresh build
                // over the surviving rows, so cloning is what keeps the
                // detached snapshot bit-identical to the in-place one
                built: match cached {
                    Some(fl) if fl.is_sparse() => Some(Arc::new(fl.as_ref().clone())),
                    _ => None,
                },
                crossover: *crossover,
                t: *t,
                build: *build,
            },
        };
        SnapshotCore {
            store,
            int_to_ext: (0..self.live()).map(|i| self.remap.external(i)).collect(),
            k: self.cfg.k,
            ss: SsParams { seed: self.window_seed(), ..self.cfg.ss.clone() },
            intermediate_eps: self.cfg.intermediate_eps,
            shards: self.cfg.shards,
            retained: self.retained_len,
            buffered: self.buffer_len,
            pool: Arc::clone(&self.pool),
            metrics: Arc::clone(&self.metrics),
        }
    }

    /// Close the session: further appends report
    /// [`ServiceError::ServiceDown`], snapshots fail. A healthy durable
    /// session logs a clean-close marker first (exactly once), so recovery
    /// reproduces the closed state. Returns the lifetime stats. Idempotent.
    pub fn close(&mut self) -> StreamStats {
        if !self.closed {
            if let Some(du) = self.durability.as_mut() {
                if du.quarantined().is_none() {
                    if let Err(e) = du.log_close() {
                        du.quarantine(e.to_string());
                        self.metrics.tracer().record_now(EventKind::Quarantine, 0, 0, 0, 0);
                    }
                }
            }
        }
        self.closed = true;
        self.stats()
    }

    pub fn stats(&self) -> StreamStats {
        StreamStats {
            appends: self.appends,
            admitted: self.admitted,
            evicted: self.evicted,
            windows: self.windows,
            ss_rounds: self.ss_rounds,
            live: self.live(),
            retained: self.retained_len,
            buffered: self.buffer_len,
            assigned: self.remap.assigned(),
            filter_peak_resident: self.filter.as_ref().map_or(0, |f| f.peak_resident()),
        }
    }

    /// Checkpoint the session now: capture the full durable image (see
    /// `stream::checkpoint`), atomically replace the checkpoint blob, and
    /// reset the WAL. Returns the covered sequence + blob size; a write
    /// failure quarantines the session (the WAL and checkpoint can no
    /// longer be trusted to agree). Errors with
    /// [`ServiceError::Rejected`] on non-durable or quarantined sessions.
    pub fn checkpoint_now(&mut self) -> Result<CheckpointInfo, ServiceError> {
        if self.closed {
            return Err(ServiceError::ServiceDown);
        }
        let wal_seq = {
            let Some(du) = self.durability.as_ref() else {
                return Err(ServiceError::Rejected {
                    reason: "checkpointing needs a durable session (open_durable)".into(),
                });
            };
            if let Some(reason) = du.quarantined() {
                return Err(ServiceError::Rejected {
                    reason: format!("session quarantined: {reason}"),
                });
            }
            du.next_seq()
        };
        let span = self.metrics.tracer().start();
        let live = self.live();
        let state = self.capture_checkpoint_state(wal_seq)?;
        let payload = super::checkpoint::encode(&state);
        let du = self.durability.as_mut().expect("checked durable above");
        match du.write_checkpoint(&payload) {
            Ok(bytes) => {
                self.metrics.add(&self.metrics.counters.checkpoints, 1);
                self.metrics.tracer().record_since(
                    EventKind::Checkpoint,
                    span,
                    wal_seq,
                    live as u64,
                    bytes as u64,
                    0,
                );
                Ok(CheckpointInfo { seq: wal_seq, bytes })
            }
            Err(e) => {
                let reason = e.to_string();
                du.quarantine(reason.clone());
                self.metrics.tracer().record_now(EventKind::Quarantine, 0, 0, 0, 0);
                Err(ServiceError::Rejected { reason })
            }
        }
    }

    /// Assemble the durable image. The storage rides the epoch-cached
    /// [`snapshot_core`](Self::snapshot_core) (so a quiet stream's
    /// checkpoints re-serialize without re-cloning), but the remap, filter
    /// and counters are read fresh from the session: an all-rejected batch
    /// advances those without touching the store, so only the store may
    /// come from the cache.
    fn capture_checkpoint_state(&mut self, wal_seq: u64) -> Result<CheckpointState, ServiceError> {
        let core = self.snapshot_core()?;
        let store = match &core.store {
            CoreStore::Features(fb) => StorePayload::Features {
                concave: fb.concave(),
                rows: fb.feats().clone(),
            },
            CoreStore::Facility { feats, built, crossover, t, build } => StorePayload::Facility {
                crossover: *crossover,
                t: *t,
                build: *build,
                rows: feats.clone(),
                sparse: built.as_ref().and_then(|fl| fl.sparse_store()).map(|s| {
                    let (n, t, len, cols, vals) = s.export_parts();
                    // only the LSH *geometry* persists — the index itself
                    // is a pure function of it and is rehashed on restore
                    let lsh = s.lsh_params().map(|(tables, bits)| {
                        (tables, bits, s.adapt_floor().map_or(0, |f| f as u32))
                    });
                    SparseParts { n, t, len, cols, vals, lsh }
                }),
            },
        };
        let (base, fwd, bwd) = self.remap.export_parts();
        let filter = self.filter.as_ref().map(|f| FilterPayload {
            max_singleton: f.max_singleton(),
            peak_resident: f.peak_resident(),
            sieves: f
                .sieves()
                .iter()
                .map(|(tau, s)| SievePayload {
                    tau: *tau,
                    value: s.value,
                    len: s.len,
                    cov: s.cov.clone(),
                })
                .collect(),
        });
        Ok(CheckpointState {
            wal_seq,
            d: self.d,
            k: self.cfg.k,
            ss: self.cfg.ss.clone(),
            high_water: self.cfg.high_water,
            max_live: self.cfg.max_live,
            admission: self.cfg.admission.clone(),
            shards: self.cfg.shards,
            intermediate_eps: self.cfg.intermediate_eps,
            reserve_hint: self.cfg.reserve_hint,
            windows: self.windows,
            ss_rounds: self.ss_rounds,
            appends: self.appends,
            admitted: self.admitted,
            evicted: self.evicted,
            closed: self.closed,
            retained_len: self.retained_len,
            buffer_len: self.buffer_len,
            base,
            ext_to_int: fwd.to_vec(),
            int_to_ext: bwd.to_vec(),
            filter,
            store,
        })
    }

    /// Rebuild a session from a decoded checkpoint. The payload already
    /// passed the frame checksum, but a checksum-valid-yet-impossible
    /// state (hand-edited, version-confused) must still surface as a typed
    /// rejection — every structural invariant is re-validated here instead
    /// of trusting the bytes into a panic or a silent divergence.
    fn from_checkpoint_state(
        state: CheckpointState,
        pool: Arc<ThreadPool>,
        metrics: Arc<Metrics>,
    ) -> Result<Self, ServiceError> {
        let reject =
            |reason: &str| ServiceError::Rejected { reason: format!("recovery failed: {reason}") };
        let cfg = StreamConfig {
            k: state.k,
            ss: state.ss,
            high_water: state.high_water,
            max_live: state.max_live,
            admission: state.admission,
            shards: state.shards,
            intermediate_eps: state.intermediate_eps,
            reserve_hint: state.reserve_hint,
        };
        // same servability gate as `new()` — a checkpoint of a session
        // that could never have been opened is corruption, not config
        if state.d == 0
            || cfg.k == 0
            || !(cfg.intermediate_eps > 0.0 && cfg.intermediate_eps < 1.0)
            || cfg.high_water < cfg.k
            || (cfg.max_live > 0 && cfg.max_live < cfg.high_water)
            || cfg.admission.as_ref().is_some_and(|p| !(p.eps > 0.0))
        {
            return Err(reject("checkpoint holds an unservable configuration"));
        }
        let store = match state.store {
            StorePayload::Features { concave, rows } => {
                if rows.d() != state.d {
                    return Err(reject("feature rows disagree with the session's d"));
                }
                if !rows.data().iter().all(|x| x.is_finite() && *x >= 0.0) {
                    return Err(reject("feature rows hold out-of-domain values"));
                }
                LiveStore::Features(Arc::new(FeatureBased::new(rows, concave)))
            }
            StorePayload::Facility { crossover, t, build, rows, sparse } => {
                if rows.d() != state.d {
                    return Err(reject("facility rows disagree with the session's d"));
                }
                if !rows.data().iter().all(|x| x.is_finite()) {
                    return Err(reject("facility rows hold non-finite values"));
                }
                let cached = match sparse {
                    Some(p) => {
                        if p.n != rows.n() {
                            return Err(reject("sparse store disagrees with the row count"));
                        }
                        let mut s = SparseSimStore::from_parts(p.n, p.t, p.len, p.cols, p.vals)
                            .map_err(|e| reject(&e))?;
                        // rehydrate the LSH index from its persisted
                        // geometry: projections are seeded, so the rebuilt
                        // index is identical to the one checkpointed and
                        // post-recovery appends stay ≡ the uncrashed run
                        if let Some((tables, bits, floor)) = p.lsh {
                            let floor = (floor > 0).then_some(floor as usize);
                            s.attach_lsh(tables, bits, floor, &rows);
                        }
                        Some(Arc::new(FacilityLocation::from_sparse_store(s)))
                    }
                    None => None,
                };
                LiveStore::Facility { feats: rows, cached, crossover, t, build }
            }
        };
        let remap = IdRemap::from_parts(state.base, state.ext_to_int, state.int_to_ext)
            .map_err(|e| reject(&e))?;
        let live = match &store {
            LiveStore::Features(fb) => fb.n(),
            LiveStore::Facility { feats, .. } => feats.n(),
        };
        if remap.live() != live || state.retained_len + state.buffer_len != live {
            return Err(reject("live-set accounting is internally inconsistent"));
        }
        let filter = match (&cfg.admission, state.filter, &store) {
            (Some(p), Some(fp), LiveStore::Features(_)) => {
                let mut sieves = Vec::with_capacity(fp.sieves.len());
                for s in fp.sieves {
                    if s.cov.len() != state.d {
                        return Err(reject("sieve coverage width disagrees with d"));
                    }
                    sieves.push((s.tau, CovSieve { cov: s.cov, value: s.value, len: s.len }));
                }
                Some(SieveFilter::restore(cfg.k, p, fp.max_singleton, fp.peak_resident, sieves))
            }
            (None, None, _) => None,
            _ => return Err(reject("admission-filter state disagrees with the configuration")),
        };
        Ok(Self {
            cfg,
            d: state.d,
            store,
            remap,
            retained_len: state.retained_len,
            buffer_len: state.buffer_len,
            filter,
            pool,
            metrics,
            parked: None,
            windows: state.windows,
            ss_rounds: state.ss_rounds,
            appends: state.appends,
            admitted: state.admitted,
            evicted: state.evicted,
            closed: state.closed,
            epoch: 0,
            core_cache: None,
            core_builds: 0,
            durability: None,
            pending_compacts: VecDeque::new(),
        })
    }

    /// Live (retained + buffered) elements.
    pub fn live(&self) -> usize {
        match &self.store {
            LiveStore::Features(fb) => fb.n(),
            LiveStore::Facility { feats, .. } => feats.n(),
        }
    }

    pub fn retained(&self) -> usize {
        self.retained_len
    }

    pub fn buffered(&self) -> usize {
        self.buffer_len
    }

    /// The feature row of a live external id; `None` once evicted (or
    /// never admitted) — external ids themselves are stable forever.
    pub fn row(&self, ext: usize) -> Option<&[f32]> {
        let int = self.remap.internal(ext)?;
        Some(match &self.store {
            LiveStore::Features(fb) => fb.feats().row(int),
            LiveStore::Facility { feats, .. } => feats.row(int),
        })
    }

    /// The id remap spine (read-only).
    pub fn remap(&self) -> &IdRemap {
        &self.remap
    }

    /// Feature dimensionality the session was opened with.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Deep snapshot-core clones actually performed (cache misses of the
    /// epoch-keyed core cache) — the counter the no-clone test asserts on.
    pub fn core_builds(&self) -> u64 {
        self.core_builds
    }

    /// Whether this session's objective requires non-negative features
    /// (feature-based coverage does; facility location accepts signed
    /// embeddings) — what [`validate_batch`](Self::validate_batch) needs.
    pub(crate) fn needs_nonneg(&self) -> bool {
        matches!(self.store, LiveStore::Features(_))
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Current objective handle (Features: the live store itself;
    /// facility location: built from the live rows when missing — from
    /// scratch at most once for a sparse store, per staleness for a dense
    /// one). The build is shard-parallel over the session pool and honors
    /// the spec's crossover/t parameters.
    fn objective(&mut self) -> Arc<dyn BatchedDivergence> {
        match &mut self.store {
            LiveStore::Features(fb) => Arc::clone(fb) as Arc<dyn BatchedDivergence>,
            LiveStore::Facility { feats, cached, crossover, t, build } => {
                if cached.is_none() {
                    let shards = if self.cfg.shards > 0 {
                        self.cfg.shards
                    } else {
                        self.pool.threads() * 2
                    };
                    *cached = Some(Arc::new(FacilityLocation::from_features_strat(
                        feats,
                        *crossover,
                        *t,
                        *build,
                        Some((self.pool.as_ref(), shards)),
                    )));
                }
                Arc::clone(cached.as_ref().unwrap()) as Arc<dyn BatchedDivergence>
            }
        }
    }

    /// This window's SS backend: resume the parked one — reusing its pool
    /// wiring, shard count and scratch — when the objective supports
    /// in-place compaction (every live store's does), falling back to
    /// fresh construction otherwise.
    fn resume_backend(&mut self, obj: &Arc<dyn BatchedDivergence>) -> ShardedBackend {
        match self.parked.take() {
            Some(p) if obj.supports_retain() => {
                p.resume(Arc::clone(obj)).expect("CPU backend resume is infallible")
            }
            _ => make_backend(obj, &self.pool, &self.metrics, self.cfg.shards),
        }
    }

    /// Per-window SS seed: window 0 is `ss.seed` itself (batch
    /// equivalence); later windows decorrelate with a golden-ratio stride.
    fn window_seed(&self) -> u64 {
        self.cfg.ss.seed.wrapping_add(self.windows.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

/// Cloned storage of a [`SnapshotCore`]. Objectives sit behind fresh
/// `Arc`s (never the session's live handles) so one cached core can be
/// shared by any number of concurrent snapshot jobs.
enum CoreStore {
    /// Deep copy of the grown objective (rows + cached totals).
    Features(Arc<FeatureBased>),
    /// Facility-location capture: the raw rows always (checkpoints need
    /// them), plus a clone of the live sparse objective when one exists —
    /// the only faithful capture once incremental appends/retains have
    /// made the store's history matter (see
    /// [`StreamSession::snapshot_core`]). With `built` absent the
    /// similarity build (dense `O(m²·d)` below the crossover, sparse
    /// top-t above it) happens in [`SnapshotCore::run`], off the session
    /// borrow, with the session's store parameters — both builds are pure
    /// per-pair functions of the rows, so the deferred build bit-matches
    /// what the session would construct.
    Facility {
        feats: FeatureMatrix,
        built: Option<Arc<FacilityLocation>>,
        crossover: usize,
        t: Option<usize>,
        build: BuildStrategy,
    },
}

/// A self-contained, immutable clone of a session's live core — everything
/// a snapshot needs to run detached from the session: storage, the
/// external-id view, this window's seed, and the pool/metrics handles. The
/// service wraps one per snapshot job; [`run`](Self::run) executes it on
/// whatever thread dequeues it while the originating session keeps
/// accepting appends.
pub struct SnapshotCore {
    store: CoreStore,
    /// internal index → stable external id, frozen at clone time
    int_to_ext: Vec<usize>,
    k: usize,
    /// window-resolved SS params (seed already fixed to the clone moment)
    ss: SsParams,
    intermediate_eps: f64,
    shards: usize,
    retained: usize,
    buffered: usize,
    pool: Arc<ThreadPool>,
    metrics: Arc<Metrics>,
}

impl SnapshotCore {
    /// Live elements captured in the core.
    pub fn live(&self) -> usize {
        self.int_to_ext.len()
    }

    /// Execute the snapshot. `check` is the cooperative cancel/deadline
    /// probe, polled between SS rounds
    /// ([`sparsify_candidates_with`](crate::algorithms::sparsify_candidates_with));
    /// pass `&mut || None` to run to completion.
    ///
    /// **Bit-identical** to the in-place
    /// [`snapshot_summary`](StreamSession::snapshot_summary) on the
    /// session the core was cloned from, at the moment it was cloned: the
    /// feature store is a deep copy, the facility-location similarity
    /// matrix is a pure per-pair function of the cloned rows (so the
    /// rebuild reproduces the compacted in-place matrix exactly), and
    /// both paths run [`summarize_live`] with the same seed, budget and
    /// backend shape. Pinned by `snapshot_core_matches_in_place_snapshot`.
    pub fn run(
        &self,
        mode: SnapshotMode,
        check: &mut dyn FnMut() -> Option<Interrupt>,
    ) -> Result<StreamSummary, Interrupt> {
        let m = self.int_to_ext.len();
        if m == 0 {
            return Ok(StreamSummary {
                summary: Vec::new(),
                value: 0.0,
                live: 0,
                retained: self.retained,
                buffered: self.buffered,
                ss_rounds: 0,
            });
        }
        let obj: Arc<dyn BatchedDivergence> = match &self.store {
            CoreStore::Features(fb) => Arc::clone(fb) as Arc<dyn BatchedDivergence>,
            CoreStore::Facility { built: Some(fl), .. } => {
                Arc::clone(fl) as Arc<dyn BatchedDivergence>
            }
            CoreStore::Facility { feats, built: None, crossover, t, build } => {
                // same store parameters and pooled build as the session's
                // own lazy construction — what keeps this path bit-identical
                // to the in-place snapshot
                let shards =
                    if self.shards > 0 { self.shards } else { self.pool.threads() * 2 };
                Arc::new(FacilityLocation::from_features_strat(
                    feats,
                    *crossover,
                    *t,
                    *build,
                    Some((self.pool.as_ref(), shards)),
                ))
            }
        };
        let backend = make_backend(&obj, &self.pool, &self.metrics, self.shards);
        let (sol, ss_rounds) = summarize_live(
            &obj,
            &backend,
            mode,
            self.k,
            self.intermediate_eps,
            &self.ss,
            m,
            check,
            self.metrics.tracer(),
        )?;
        Ok(StreamSummary {
            summary: sol.set.iter().map(|&i| self.int_to_ext[i]).collect(),
            value: sol.value,
            live: m,
            retained: self.retained,
            buffered: self.buffered,
            ss_rounds,
        })
    }
}

/// CPU sharded backend over a live-set objective — the one construction
/// both the in-place and the copy-on-snapshot paths use, so their backends
/// can never differ in shape.
fn make_backend(
    obj: &Arc<dyn BatchedDivergence>,
    pool: &Arc<ThreadPool>,
    metrics: &Arc<Metrics>,
    shards: usize,
) -> ShardedBackend {
    let b = ShardedBackend::new(
        Arc::clone(obj),
        Arc::clone(pool),
        Compute::Cpu,
        Arc::clone(metrics),
    )
    .expect("CPU backend construction is infallible");
    if shards > 0 {
        b.with_shards(shards)
    } else {
        b
    }
}

/// The one snapshot compute path (shared by
/// [`StreamSession::snapshot_summary`] and [`SnapshotCore::run`], which is
/// what makes them bit-identical): [`SnapshotMode::Final`] runs
/// `sparsify → lazy greedy` with this window's seed,
/// [`SnapshotMode::Intermediate`] stochastic greedy over the live set. `m`
/// is the live count (== `backend.n()`); solutions come back in internal
/// indices for the caller to map through its id view.
#[allow(clippy::too_many_arguments)]
fn summarize_live(
    obj: &Arc<dyn BatchedDivergence>,
    backend: &ShardedBackend,
    mode: SnapshotMode,
    k: usize,
    intermediate_eps: f64,
    params: &SsParams,
    m: usize,
    check: &mut dyn FnMut() -> Option<Interrupt>,
    tracer: &Tracer,
) -> Result<(Solution, usize), Interrupt> {
    let mut engine = MaximizerEngine::new(obj.as_submodular(), GainRoute::Backend(backend))
        .with_tracer(tracer);
    match mode {
        SnapshotMode::Final => {
            let ss = sparsify_traced(backend, params, check, tracer)?;
            // the probe rides into the greedy epoch loop too, so a cancel
            // landing after the SS pass sheds within one cohort
            Ok((engine.lazy_greedy_with(&ss.kept, k, check)?, ss.rounds))
        }
        SnapshotMode::Intermediate => {
            // only the stochastic route needs an explicit candidate list
            let candidates: Vec<usize> = (0..m).collect();
            Ok((
                engine.stochastic_greedy_with(&candidates, k, intermediate_eps, params.seed, check)?,
                0,
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submodular::Concave;
    use crate::util::rng::Rng;

    fn rows(n: usize, d: usize, seed: u64) -> FeatureMatrix {
        let mut rng = Rng::new(seed);
        let mut m = FeatureMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.row_mut(i)[j] = if rng.bool(0.3) { rng.f32() } else { 0.0 };
            }
        }
        m
    }

    fn session(cfg: StreamConfig, d: usize) -> StreamSession {
        StreamSession::new(
            ObjectiveSpec::Features(Concave::Sqrt),
            d,
            cfg,
            Arc::new(ThreadPool::new(2, 16)),
            Arc::new(Metrics::new()),
        )
        .unwrap()
    }

    #[test]
    fn append_snapshot_roundtrip_full_window() {
        let data = rows(300, 12, 1);
        let mut s = session(StreamConfig::new(8).with_ss(SsParams::default().with_seed(5)), 12);
        let r = s.append(data.data()).unwrap();
        assert_eq!(r.appended, 300);
        assert_eq!(r.admitted, 300, "no filter => everything admitted");
        assert_eq!(r.resparsifies, 0, "full window never re-sparsifies");
        assert_eq!(s.live(), 300);
        let snap = s.snapshot_summary(SnapshotMode::Final).unwrap();
        assert_eq!(snap.summary.len(), 8);
        assert!(snap.value > 0.0);
        assert!(snap.ss_rounds > 0);
        assert!(snap.summary.iter().all(|&e| e < 300));
        // deterministic given the same stream + seed
        let mut s2 = session(StreamConfig::new(8).with_ss(SsParams::default().with_seed(5)), 12);
        s2.append(data.data()).unwrap();
        let snap2 = s2.snapshot_summary(SnapshotMode::Final).unwrap();
        assert_eq!(snap.summary, snap2.summary);
        assert_eq!(snap.value.to_bits(), snap2.value.to_bits());
    }

    #[test]
    fn windowing_bounds_live_set_and_keeps_ids_stable() {
        let data = rows(1200, 10, 2);
        let mut s = session(
            StreamConfig::new(6)
                .with_ss(SsParams::default().with_seed(3))
                .with_high_water(200),
            10,
        );
        let r = s.append(data.data()).unwrap();
        assert!(r.resparsifies >= 2, "1200 appends over hw=200 must window repeatedly");
        assert!(r.evicted > 0);
        assert!(s.live() < 1200, "live set must stay bounded");
        assert_eq!(s.buffered() + s.retained(), s.live());
        assert_eq!(s.stats().windows, r.resparsifies as u64);
        // every surviving external id still resolves to its original row
        let mut survivors = 0;
        for ext in 0..1200 {
            if let Some(row) = s.row(ext) {
                assert_eq!(row, data.row(ext), "ext {ext} must keep its row across evictions");
                survivors += 1;
            }
        }
        assert_eq!(survivors, s.live());
        // the remap's dead prefix was compacted away behind base()
        assert!(s.remap().base() > 0, "multiple windows must strand a dead prefix");
        assert_eq!(s.remap().map_residue(), s.remap().assigned() - s.remap().base());
        // snapshots speak external ids
        let snap = s.snapshot_summary(SnapshotMode::Intermediate).unwrap();
        assert_eq!(snap.summary.len(), 6);
        for &e in &snap.summary {
            assert!(s.row(e).is_some(), "summary must reference live external ids");
        }
    }

    #[test]
    fn admission_filter_screens_arrivals() {
        // near-duplicate heavy stream: the sieve grid should reject a
        // solid fraction of arrivals before they ever get storage
        let mut base = rows(8, 10, 4);
        base.scale(2.0);
        let mut s = session(
            StreamConfig::new(4)
                .with_ss(SsParams::default().with_seed(1))
                .with_admission(SieveParams::paper_default()),
            10,
        );
        let mut rng = Rng::new(9);
        let mut batch = FeatureMatrix::zeros(0, 10);
        for _ in 0..400 {
            let b = rng.below(8);
            let mut row = base.row(b).to_vec();
            for x in &mut row {
                *x = (*x + 0.01 * rng.f32()).max(0.0);
            }
            batch.push_row(&row);
        }
        let r = s.append(batch.data()).unwrap();
        assert_eq!(r.appended, 400);
        assert!(r.admitted < 400, "redundant stream must see rejections");
        assert!(r.admitted >= 1);
        assert_eq!(s.live(), r.admitted);
        let st = s.stats();
        assert_eq!(st.assigned, 400, "every arrival gets an external id");
        assert!(st.filter_peak_resident > 0);
        assert!(st.filter_peak_resident <= 50 * 4, "paper bound: 50·k");
        // rejected ids resolve to None, admitted ones to their row
        let mut live_seen = 0;
        for ext in 0..400 {
            if s.row(ext).is_some() {
                live_seen += 1;
            }
        }
        assert_eq!(live_seen, s.live());
        let snap = s.snapshot_summary(SnapshotMode::Final).unwrap();
        assert_eq!(snap.summary.len(), 4);
    }

    #[test]
    fn backpressure_and_close_semantics() {
        let data = rows(600, 8, 7);
        let mut s = session(
            StreamConfig::new(5)
                .with_ss(SsParams::default().with_seed(2).with_min_keep(10))
                .with_high_water(100)
                .with_max_live(240),
            8,
        );
        // feed in chunks; all should fit thanks to forced re-sparsification
        for c in data.data().chunks(8 * 120) {
            s.append(c).unwrap();
        }
        assert!(s.live() <= 240);
        // a batch larger than the cap itself must shed
        let huge = rows(300, 8, 8);
        match s.append(huge.data()) {
            Err(e @ ServiceError::QueueFull(())) => assert!(e.is_retryable()),
            other => panic!("expected QueueFull, got {:?}", other.map(|r| r.appended)),
        }
        let before = s.stats();
        let _ = s.close();
        match s.append(data.data()) {
            Err(e @ ServiceError::ServiceDown) => assert!(!e.is_retryable()),
            _ => panic!("closed session must report ServiceDown"),
        }
        match s.snapshot_summary(SnapshotMode::Final) {
            Err(ServiceError::ServiceDown) => {}
            _ => panic!("closed session must refuse snapshots"),
        }
        match s.snapshot_core() {
            Err(ServiceError::ServiceDown) => {}
            _ => panic!("closed session must refuse snapshot cores"),
        }
        assert_eq!(s.stats().appends, before.appends, "closed session accepts nothing");
    }

    #[test]
    fn facility_location_sessions_work_and_reject_admission() {
        let data = rows(200, 9, 11);
        let pool = Arc::new(ThreadPool::new(2, 16));
        let mut s = StreamSession::new(
            ObjectiveSpec::FacilityLocation,
            9,
            StreamConfig::new(6).with_ss(SsParams::default().with_seed(4)).with_high_water(60),
            Arc::clone(&pool),
            Arc::new(Metrics::new()),
        )
        .unwrap();
        s.append(data.data()).unwrap();
        assert!(s.live() < 200);
        let snap = s.snapshot_summary(SnapshotMode::Final).unwrap();
        assert_eq!(snap.summary.len(), 6);
        assert!(snap.value > 0.0);
        // admission filter is features-only, reported as a typed rejection
        match StreamSession::new(
            ObjectiveSpec::FacilityLocation,
            9,
            StreamConfig::new(6).with_admission(SieveParams::paper_default()),
            pool,
            Arc::new(Metrics::new()),
        ) {
            Err(ServiceError::Rejected { reason }) => {
                assert!(reason.contains("admission"), "{reason}")
            }
            _ => panic!("facility location + admission filter must be rejected"),
        }
    }

    #[test]
    fn sparse_facility_sessions_ride_the_store_across_windows() {
        let ord = std::sync::atomic::Ordering::Relaxed;
        let data = rows(260, 9, 41);
        let metrics = Arc::new(Metrics::new());
        let mut s = StreamSession::new(
            ObjectiveSpec::FacilityLocationSparse {
                t: 24,
                crossover: 0,
                build: BuildStrategy::Auto,
            },
            9,
            StreamConfig::new(6).with_ss(SsParams::default().with_seed(4)).with_high_water(80),
            Arc::new(ThreadPool::new(2, 16)),
            Arc::clone(&metrics),
        )
        .unwrap();
        let r = s.append(data.data()).unwrap();
        assert!(r.resparsifies >= 1, "260 appends over hw=80 must window");
        // after the first window the sparse store is live: the rest of the
        // batch grows it by row-border insertion instead of invalidating it
        assert!(
            metrics.counters.neighbor_updates.load(ord) > 0,
            "post-window appends must ride the incremental path"
        );
        let snap = s.snapshot_summary(SnapshotMode::Final).unwrap();
        assert_eq!(snap.summary.len(), 6);
        assert!(snap.value > 0.0);
        assert_eq!(
            metrics.counters.sparse_rows.load(ord) as usize,
            s.live(),
            "the resumed backend must gauge the sparse residency"
        );
        // the detached snapshot clones the live store, so it stays
        // bit-identical to the in-place path even though the store's
        // history (appends + evictions) is not reproducible from the rows
        let core = s.snapshot_core().unwrap();
        let detached = core.run(SnapshotMode::Final, &mut || None).unwrap();
        let in_place = s.snapshot_summary(SnapshotMode::Final).unwrap();
        assert_eq!(detached.summary, in_place.summary);
        assert_eq!(detached.value.to_bits(), in_place.value.to_bits());
        // further appends keep growing the same store
        let before = metrics.counters.neighbor_updates.load(ord);
        let more = rows(30, 9, 42);
        s.append(more.data()).unwrap();
        assert!(metrics.counters.neighbor_updates.load(ord) > before);
    }

    #[test]
    fn stream_metrics_are_counted() {
        let data = rows(500, 8, 13);
        let metrics = Arc::new(Metrics::new());
        let mut s = StreamSession::new(
            ObjectiveSpec::Features(Concave::Sqrt),
            8,
            StreamConfig::new(5).with_ss(SsParams::default().with_seed(6)).with_high_water(120),
            Arc::new(ThreadPool::new(2, 16)),
            Arc::clone(&metrics),
        )
        .unwrap();
        let r = s.append(data.data()).unwrap();
        let snap = metrics.snapshot();
        let get = |k: &str| snap.get(k).unwrap().as_f64().unwrap();
        assert_eq!(get("stream_appends"), 500.0);
        assert_eq!(get("stream_admitted"), 500.0);
        assert_eq!(get("resparsify_rounds") as usize, r.ss_rounds);
        assert_eq!(get("evicted_elements") as usize, r.evicted);
        assert!(get("divergence_evals") > 0.0, "windowed SS must meter its backend");
    }

    #[test]
    fn snapshot_core_matches_in_place_snapshot() {
        // the acceptance invariant: the copy-on-snapshot job produces the
        // bit-identical summary of the lock-holding in-place path on a
        // quiesced session — across objectives, modes, and sessions that
        // have already windowed (non-trivial remap, compacted storage)
        for spec in [ObjectiveSpec::Features(Concave::Sqrt), ObjectiveSpec::FacilityLocation] {
            let n = if spec == ObjectiveSpec::FacilityLocation { 220 } else { 420 };
            let data = rows(n, 10, 19);
            let mut s = StreamSession::new(
                spec,
                10,
                StreamConfig::new(7)
                    .with_ss(SsParams::default().with_seed(23))
                    .with_high_water(90),
                Arc::new(ThreadPool::new(2, 16)),
                Arc::new(Metrics::new()),
            )
            .unwrap();
            let r = s.append(data.data()).unwrap();
            assert!(r.resparsifies >= 1, "{spec:?}: session must have windowed");
            for mode in [SnapshotMode::Final, SnapshotMode::Intermediate] {
                let core = s.snapshot_core().unwrap();
                assert_eq!(core.live(), s.live());
                let detached = core.run(mode, &mut || None).unwrap();
                let in_place = s.snapshot_summary(mode).unwrap();
                assert_eq!(
                    detached.summary, in_place.summary,
                    "{spec:?}/{mode:?}: summaries diverged"
                );
                assert_eq!(
                    detached.value.to_bits(),
                    in_place.value.to_bits(),
                    "{spec:?}/{mode:?}: value bits diverged"
                );
                assert_eq!(detached.live, in_place.live);
                assert_eq!(detached.retained, in_place.retained);
                assert_eq!(detached.buffered, in_place.buffered);
                assert_eq!(detached.ss_rounds, in_place.ss_rounds);
            }
        }
    }

    #[test]
    fn snapshot_core_is_isolated_from_later_appends() {
        // the core freezes the session state at clone time: appends that
        // land after the clone affect neither its result nor its seed
        let data = rows(500, 9, 29);
        let mut s = session(
            StreamConfig::new(6)
                .with_ss(SsParams::default().with_seed(31))
                .with_high_water(150),
            9,
        );
        s.append(&data.data()[..300 * 9]).unwrap();
        let frozen = s.snapshot_summary(SnapshotMode::Final).unwrap();
        let core = s.snapshot_core().unwrap();
        // mutate the session heavily after the clone
        s.append(&data.data()[300 * 9..]).unwrap();
        assert_eq!(s.stats().appends, 500, "appends landed after the clone");
        let detached = core.run(SnapshotMode::Final, &mut || None).unwrap();
        assert_eq!(detached.summary, frozen.summary);
        assert_eq!(detached.value.to_bits(), frozen.value.to_bits());
        assert_eq!(detached.live, frozen.live);
        // and the session still snapshots its *new* state fine
        let fresh = s.snapshot_summary(SnapshotMode::Final).unwrap();
        assert_eq!(fresh.live, s.live());
    }

    #[test]
    fn snapshot_core_honors_the_interrupt_probe() {
        let data = rows(600, 8, 37);
        let mut s = session(StreamConfig::new(5).with_ss(SsParams::default().with_seed(3)), 8);
        s.append(data.data()).unwrap();
        let core = s.snapshot_core().unwrap();
        let err = core.run(SnapshotMode::Final, &mut || Some(Interrupt::Cancelled)).unwrap_err();
        assert_eq!(err, Interrupt::Cancelled);
        // an empty core ignores the probe (nothing to do)
        let mut empty = session(StreamConfig::new(5), 8);
        let snap = empty
            .snapshot_core()
            .unwrap()
            .run(SnapshotMode::Final, &mut || Some(Interrupt::Cancelled))
            .unwrap();
        assert_eq!(snap.live, 0);
        assert!(snap.summary.is_empty());
    }

    #[test]
    fn unservable_configs_are_rejected_at_open() {
        let pool = Arc::new(ThreadPool::new(2, 16));
        let open = |cfg: StreamConfig| {
            StreamSession::new(
                ObjectiveSpec::Features(Concave::Sqrt),
                6,
                cfg,
                Arc::clone(&pool),
                Arc::new(Metrics::new()),
            )
        };
        // high_water below the budget starves every snapshot
        match open(StreamConfig::new(8).with_high_water(4)) {
            Err(ServiceError::Rejected { reason }) => assert!(reason.contains("high_water")),
            _ => panic!("hw < k must be rejected"),
        }
        // max_live below high_water sheds every batch that tries to fill
        // the window
        match open(StreamConfig::new(4).with_high_water(100).with_max_live(50)) {
            Err(ServiceError::Rejected { reason }) => assert!(reason.contains("max_live")),
            _ => panic!("max_live < high_water must be rejected"),
        }
        // boundary shapes stay servable
        assert!(open(StreamConfig::new(8).with_high_water(8)).is_ok());
        assert!(open(StreamConfig::new(4).with_high_water(100).with_max_live(100)).is_ok());
        assert!(open(StreamConfig::new(4).with_max_live(0)).is_ok(), "0 stays uncapped");
    }

    #[test]
    fn snapshot_core_cache_skips_clones_on_quiet_streams() {
        let data = rows(300, 10, 51);
        let mut s = session(
            StreamConfig::new(6).with_ss(SsParams::default().with_seed(9)).with_high_water(120),
            10,
        );
        s.append(&data.data()[..200 * 10]).unwrap();
        assert_eq!(s.core_builds(), 0);
        let a = s.snapshot_core().unwrap();
        let b = s.snapshot_core().unwrap();
        assert!(Arc::ptr_eq(&a, &b), "quiet stream must share one cached core");
        assert_eq!(s.core_builds(), 1, "two snapshots, one deep clone");
        // both handles still run (and agree bit-for-bit)
        let ra = a.run(SnapshotMode::Final, &mut || None).unwrap();
        let rb = b.run(SnapshotMode::Final, &mut || None).unwrap();
        assert_eq!(ra.summary, rb.summary);
        assert_eq!(ra.value.to_bits(), rb.value.to_bits());
        // an admitted append invalidates the cache...
        s.append(&data.data()[200 * 10..]).unwrap();
        let c = s.snapshot_core().unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "mutation must invalidate the cached core");
        assert_eq!(s.core_builds(), 2);
        // ...and the fresh core is cached again
        let d = s.snapshot_core().unwrap();
        assert!(Arc::ptr_eq(&c, &d));
        assert_eq!(s.core_builds(), 2);
    }
}
