//! The distributed SS cluster: worker runtime + fan-out coordinator.
//!
//! This is the paper's §1.2 composition — SS commutes with two-round
//! distributed maximization — promoted from the in-process demo
//! (`examples/distributed_coreset.rs`) to real processes over the
//! [`crate::net`] wire protocol:
//!
//! 1. the [`ClusterCoordinator`] partitions the ground set into logical
//!    shards (seed-deterministic, worker-count-independent) and fans
//!    `ShardAssign` frames out over its connections;
//! 2. each [`WorkerRuntime`] runs the shard's SS pass on its embedded
//!    [`SummarizationService`](crate::coordinator::SummarizationService)
//!    and streams the survivor core back;
//! 3. the coordinator unions the cores and finishes with one central
//!    SS + maximizer pass.
//!
//! Worker death, stragglers and corrupt streams surface as typed
//! [`ServiceError`](crate::coordinator::ServiceError)s and bounded
//! reshard-and-retry — see [`coordinator`] for the invariants and
//! [`worker`] for the connection protocol.

pub mod coordinator;
pub mod worker;

pub use coordinator::{ClusterConfig, ClusterCoordinator, ClusterResponse, WorkerHealth};
pub use worker::{WorkerConfig, WorkerReport, WorkerRuntime};
