//! The worker runtime: one process (or thread) that serves the existing
//! job-oriented [`SummarizationService`] over a [`Transport`].
//!
//! A connection is a conversation: the coordinator opens with `Hello`,
//! the worker answers `HelloAck` (or a typed version-mismatch error),
//! and from then on the worker turns `ShardAssign` / `SummarizeReq`
//! frames into service jobs and streams the results back as
//! `ShardCore` / `SummarizeResp` / `ErrorMsg` frames. The protocol is
//! fully pipelined — the reader loop never blocks on compute:
//!
//! * the **reader** (the caller's thread) decodes frames and submits
//!   jobs to the service, which runs them on its own worker pool;
//! * one **waiter thread per in-flight job** blocks on the service
//!   [`Ticket`](crate::coordinator::Ticket) and pushes the completion
//!   message into an outbound channel — slow shards don't head-of-line
//!   block fast ones;
//! * one **writer thread** owns the [`FrameWriter`] (and therefore the
//!   outbound sequence numbers) and drains that channel.
//!
//! `Cancel{job}` flips a per-job flag the waiter polls, which cancels
//! the underlying ticket — the service sheds the job at dequeue or at
//! the next SS round boundary, and the coordinator gets a typed
//! `Cancelled` error frame. A corrupt or reordered inbound stream is
//! answered with a typed error frame and connection teardown (never a
//! panic, never partial state: jobs already running complete or cancel,
//! nothing half-decoded is acted on).

use std::collections::HashMap;
use std::net::{TcpListener, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{
    Metrics, PruneRequest, ServiceConfig, ServiceError, SummarizationService, SummarizeRequest,
};
use crate::net::{
    stdio_transport, tcp_transport, FrameReader, FrameWriter, Message, Transport, WireError,
    PROTO_VERSION,
};
use crate::trace::EventKind;

/// How long a job waiter sleeps between cancel-flag polls. Small enough
/// that cancel propagation is prompt, large enough to cost nothing.
const WAITER_POLL: Duration = Duration::from_millis(10);

#[derive(Clone)]
pub struct WorkerConfig {
    /// The embedded service's sizing (request workers, queue, compute).
    pub service: ServiceConfig,
    /// Identity reported in the handshake and the metrics scope label.
    pub worker_id: u64,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        Self { service: ServiceConfig::default(), worker_id: 0 }
    }
}

/// What one connection did, returned when it ends.
#[derive(Debug, Clone, Default)]
pub struct WorkerReport {
    /// Jobs that resolved successfully and were answered with a result.
    pub jobs_done: u64,
    /// Jobs that resolved with a typed error (answered with `ErrorMsg`).
    pub job_errors: u64,
    /// Whether the peer ended the conversation with an explicit
    /// `Shutdown` (vs just closing its end).
    pub saw_shutdown: bool,
}

/// Serves a [`SummarizationService`] to one coordinator at a time. See
/// the module docs for the threading model.
pub struct WorkerRuntime {
    config: WorkerConfig,
    metrics: Arc<Metrics>,
}

/// Everything a waiter thread needs to turn a finished job into an
/// outbound frame.
struct JobCtx {
    job: u64,
    out: Sender<Message>,
    cancel: Arc<AtomicBool>,
    registry: Arc<Mutex<HashMap<u64, Arc<AtomicBool>>>>,
    done: Arc<AtomicU64>,
    errored: Arc<AtomicU64>,
}

impl WorkerRuntime {
    pub fn new(config: WorkerConfig) -> Self {
        let metrics = Arc::new(Metrics::scoped(&format!("worker-{}", config.worker_id)));
        Self { config, metrics }
    }

    /// The runtime's own metrics scope (`worker-{id}`): wire counters
    /// plus everything the embedded service meters per connection.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Serve one connection until `Shutdown`, peer EOF, or a wire error.
    pub fn serve(&self, transport: Box<dyn Transport>) -> Result<WorkerReport, WireError> {
        let (r, w) = transport.split();
        let mut reader = FrameReader::new(r);

        // the writer thread owns the FrameWriter, and with it the
        // outbound seq counter — every other thread sends through `out`
        let (out, out_rx) = channel::<Message>();
        let writer_metrics = Arc::clone(&self.metrics);
        let writer: JoinHandle<Result<(), WireError>> = std::thread::Builder::new()
            .name("ss-net-writer".into())
            .spawn(move || {
                let mut fw = FrameWriter::new(w);
                while let Ok(msg) = out_rx.recv() {
                    let (job, shard) = msg_job_shard(&msg);
                    let tag = msg.tag();
                    let bytes = fw.send(&msg)?;
                    writer_metrics.add(&writer_metrics.counters.rpc_frames_sent, 1);
                    writer_metrics.add(&writer_metrics.counters.rpc_bytes_sent, bytes as u64);
                    writer_metrics.tracer().record_now(
                        EventKind::RpcSend,
                        tag as u64,
                        bytes as u64,
                        job,
                        shard,
                    );
                }
                Ok(())
            })
            .expect("spawn net writer");

        let result = self.serve_reader(&mut reader, &out);

        // release the writer: drop our sender, join the waiters (they
        // hold clones and flush their completions first), then reap
        drop(out);
        let (report, waiters) = match result {
            Ok(v) => v,
            Err(e) => {
                let _ = writer.join();
                return Err(e);
            }
        };
        for h in waiters {
            let _ = h.join();
        }
        let _ = writer.join();
        Ok(report)
    }

    /// The reader loop. Returns the report and the waiter handles still
    /// to be joined; wire errors have already been answered with a typed
    /// error frame by the time they propagate out of here.
    #[allow(clippy::type_complexity)]
    fn serve_reader(
        &self,
        reader: &mut FrameReader,
        out: &Sender<Message>,
    ) -> Result<(WorkerReport, Vec<JoinHandle<()>>), WireError> {
        let metrics = &self.metrics;

        // handshake: the coordinator speaks first
        match self.recv_metered(reader)? {
            Some(Message::Hello { version, peer_id: _ }) => {
                if version != PROTO_VERSION {
                    let err = WireError::Version { ours: PROTO_VERSION, theirs: version };
                    let _ = out.send(Message::ErrorMsg {
                        job: 0,
                        err: ServiceError::Rejected { reason: err.to_string() },
                    });
                    return Err(err);
                }
                let _ = out.send(Message::HelloAck {
                    version: PROTO_VERSION,
                    peer_id: self.config.worker_id,
                });
            }
            Some(other) => {
                let err =
                    WireError::Corrupt(format!("expected Hello, got tag {}", other.tag()));
                let _ = out.send(Message::ErrorMsg {
                    job: 0,
                    err: ServiceError::Rejected { reason: err.to_string() },
                });
                return Err(err);
            }
            None => return Ok((WorkerReport::default(), Vec::new())),
        }

        let svc = SummarizationService::start(self.config.service.clone(), None);
        let registry: Arc<Mutex<HashMap<u64, Arc<AtomicBool>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let done = Arc::new(AtomicU64::new(0));
        let errored = Arc::new(AtomicU64::new(0));
        let mut waiters: Vec<JoinHandle<()>> = Vec::new();
        let mut saw_shutdown = false;

        loop {
            let msg = match self.recv_metered(reader) {
                Ok(Some(m)) => m,
                Ok(None) => break, // peer closed cleanly
                Err(e) => {
                    // answer corruption with a typed error, then tear down
                    metrics.add(&metrics.counters.wire_decode_errors, 1);
                    let _ = out.send(Message::ErrorMsg {
                        job: 0,
                        err: ServiceError::Rejected { reason: format!("wire: {e}") },
                    });
                    // the queued error frame still flushes: waiters and the
                    // writer drain after this returns
                    for h in waiters {
                        let _ = h.join();
                    }
                    return Err(e);
                }
            };
            match msg {
                Message::ShardAssign { job, shard, spec, params, ids, rows } => {
                    let cancel = self.register(&registry, job);
                    let ticket = svc.submit_prune(PruneRequest {
                        spec,
                        rows,
                        params,
                        shard: shard as u64,
                    });
                    let ctx = self.job_ctx(job, out, cancel, &registry, &done, &errored);
                    waiters.push(spawn_waiter(ticket, ctx, move |resp| Message::ShardCore {
                        job,
                        shard,
                        kept: resp.kept.iter().map(|&i| ids[i]).collect(),
                        rounds: resp.rounds as u32,
                    }));
                }
                Message::SummarizeReq { job, spec, rows, k, params } => {
                    let cancel = self.register(&registry, job);
                    let ticket =
                        svc.submit(SummarizeRequest::from_rows(spec, rows, k as usize, params));
                    let ctx = self.job_ctx(job, out, cancel, &registry, &done, &errored);
                    waiters.push(spawn_waiter(ticket, ctx, move |resp| Message::SummarizeResp {
                        job,
                        summary: resp.summary.iter().map(|&i| i as u64).collect(),
                        value: resp.value,
                        n: resp.n as u64,
                        reduced: resp.reduced as u64,
                        ss_rounds: resp.ss_rounds as u32,
                    }));
                }
                Message::Cancel { job } => {
                    if let Some(flag) =
                        registry.lock().unwrap_or_else(|p| p.into_inner()).get(&job)
                    {
                        flag.store(true, Ordering::SeqCst);
                    }
                }
                Message::HealthProbe { nonce } => {
                    let busy =
                        registry.lock().unwrap_or_else(|p| p.into_inner()).len() as u32;
                    let _ = out.send(Message::HealthSnap {
                        nonce,
                        jobs_done: done.load(Ordering::SeqCst),
                        busy,
                        metrics_json: svc.metrics_json(),
                    });
                }
                Message::Shutdown => {
                    saw_shutdown = true;
                    break;
                }
                other => {
                    let err = WireError::Corrupt(format!(
                        "unexpected message tag {} on the worker side",
                        other.tag()
                    ));
                    metrics.add(&metrics.counters.wire_decode_errors, 1);
                    let _ = out.send(Message::ErrorMsg {
                        job: 0,
                        err: ServiceError::Rejected { reason: err.to_string() },
                    });
                    for h in waiters {
                        let _ = h.join();
                    }
                    return Err(err);
                }
            }
        }

        let report = WorkerReport {
            jobs_done: done.load(Ordering::SeqCst),
            job_errors: errored.load(Ordering::SeqCst),
            saw_shutdown,
        };
        Ok((report, waiters))
    }

    fn recv_metered(&self, reader: &mut FrameReader) -> Result<Option<Message>, WireError> {
        match reader.recv()? {
            Some((msg, bytes)) => {
                let m = &self.metrics;
                m.add(&m.counters.rpc_frames_recv, 1);
                m.add(&m.counters.rpc_bytes_recv, bytes as u64);
                let (job, shard) = msg_job_shard(&msg);
                m.tracer().record_now(EventKind::RpcRecv, msg.tag() as u64, bytes as u64, job, shard);
                Ok(Some(msg))
            }
            None => Ok(None),
        }
    }

    fn register(
        &self,
        registry: &Arc<Mutex<HashMap<u64, Arc<AtomicBool>>>>,
        job: u64,
    ) -> Arc<AtomicBool> {
        let flag = Arc::new(AtomicBool::new(false));
        registry
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .insert(job, Arc::clone(&flag));
        flag
    }

    fn job_ctx(
        &self,
        job: u64,
        out: &Sender<Message>,
        cancel: Arc<AtomicBool>,
        registry: &Arc<Mutex<HashMap<u64, Arc<AtomicBool>>>>,
        done: &Arc<AtomicU64>,
        errored: &Arc<AtomicU64>,
    ) -> JobCtx {
        JobCtx {
            job,
            out: out.clone(),
            cancel,
            registry: Arc::clone(registry),
            done: Arc::clone(done),
            errored: Arc::clone(errored),
        }
    }

    /// Serve the process's stdio — the `ssctl worker --stdio` deployment.
    /// stdout is the protocol channel; anything logged must go to stderr.
    pub fn serve_stdio(&self) -> Result<WorkerReport, WireError> {
        self.serve(Box::new(stdio_transport()))
    }

    /// Bind `addr` and serve connections sequentially until one of them
    /// ends with an explicit `Shutdown`.
    pub fn serve_tcp<A: ToSocketAddrs>(&self, addr: A) -> Result<WorkerReport, WireError> {
        let listener = TcpListener::bind(addr).map_err(|e| WireError::Io(e.to_string()))?;
        loop {
            let (stream, _) = listener.accept().map_err(|e| WireError::Io(e.to_string()))?;
            let conn = tcp_transport(stream).map_err(|e| WireError::Io(e.to_string()))?;
            let report = self.serve(Box::new(conn))?;
            if report.saw_shutdown {
                return Ok(report);
            }
        }
    }
}

/// The `job`/`shard` pair a message is about, for trace payloads
/// (0 where the message has no such notion).
fn msg_job_shard(msg: &Message) -> (u64, u64) {
    match msg {
        Message::SummarizeReq { job, .. }
        | Message::SummarizeResp { job, .. }
        | Message::ErrorMsg { job, .. }
        | Message::Cancel { job } => (*job, 0),
        Message::ShardAssign { job, shard, .. } | Message::ShardCore { job, shard, .. } => {
            (*job, *shard as u64)
        }
        _ => (0, 0),
    }
}

/// One thread per in-flight job: poll the ticket (and the cancel flag),
/// then turn the outcome into the completion frame. `render` maps the
/// success payload; errors become typed `ErrorMsg` frames verbatim.
fn spawn_waiter<T: Send + 'static>(
    mut ticket: crate::coordinator::Ticket<T>,
    ctx: JobCtx,
    render: impl FnOnce(T) -> Message + Send + 'static,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("ss-job-{}", ctx.job))
        .spawn(move || {
            let result = loop {
                if ctx.cancel.load(Ordering::SeqCst) {
                    ticket.cancel();
                }
                if let Some(r) = ticket.wait_timeout(WAITER_POLL) {
                    break r;
                }
            };
            ctx.registry.lock().unwrap_or_else(|p| p.into_inner()).remove(&ctx.job);
            let msg = match result {
                Ok(v) => {
                    ctx.done.fetch_add(1, Ordering::SeqCst);
                    render(v)
                }
                Err(e) => {
                    ctx.errored.fetch_add(1, Ordering::SeqCst);
                    Message::ErrorMsg { job: ctx.job, err: e }
                }
            };
            // a send failure just means the connection is already gone
            let _ = ctx.out.send(msg);
        })
        .expect("spawn job waiter")
}
