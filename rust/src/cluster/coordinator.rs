//! The fan-out coordinator: the paper's two-round distributed scheme
//! (§1.2) over real connections.
//!
//! # Partition invariance
//!
//! The ground set is cut into `M` **logical shards** fixed by
//! [`ClusterConfig::shards`] — *not* by the worker count. The partition
//! permutation is drawn from [`ClusterConfig::seed`], each shard's SS
//! pass runs under a seed derived from the request seed and the *shard
//! index*, and the final merge depends only on the (sorted) union of
//! shard survivors. Workers are merely where shards happen to execute:
//! 1 worker or N workers, healthy run or mid-run death-and-reshard, the
//! survivor union — and therefore the final summary — is **bit
//! identical**. That is the invariant `tests/cluster_e2e.rs` pins.
//!
//! # Failure handling
//!
//! Every shard dispatch is a service [`Ticket`](crate::coordinator::Ticket)
//! resolved by the connection's reader thread. A worker dying (transport
//! error, EOF, corrupt stream) drops that connection's pending responders,
//! so outstanding tickets resolve `ServiceDown` and their shards reshard
//! onto surviving workers — bounded by [`ClusterConfig::max_retries`]
//! attempts per shard. A straggler past
//! [`ClusterConfig::shard_timeout`] is cancelled on its worker and
//! resharded the same way. A blown request deadline cancels every
//! in-flight shard and surfaces as the same typed
//! [`ServiceError::DeadlineExceeded`] the local service returns.
//!
//! # Observability
//!
//! The coordinator owns a `"cluster"` scope (merge-pass compute, wire
//! totals) plus one `"cluster-worker-{i}"` scope per connection
//! (per-worker frames/bytes, `RpcSend`/`RpcRecv` spans, a `ShardPrune`
//! span per shard completion as observed from the coordinator). The
//! merge pass closes with an [`EventKind::Merge`] span, so one trace
//! export shows the whole run: fan-out, per-shard prunes, merge.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::algorithms::{
    sparsify_candidates_traced, GainRoute, Interrupt, MaximizerEngine, SsParams,
};
use crate::coordinator::job::{job_channel, JobOptions, Responder};
use crate::coordinator::{Compute, Metrics, ServiceError, ShardedBackend, Ticket};
use crate::net::{FrameReader, FrameWriter, Message, Transport, WireError, PROTO_VERSION};
use crate::submodular::ObjectiveSpec;
use crate::trace::EventKind;
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;
use crate::util::stats::Timer;
use crate::util::vecmath::FeatureMatrix;

/// How the coordinator partitions, retries and times out. `shards` is
/// the *logical* partition arity — results are invariant to the worker
/// count precisely because this number is configuration, not topology.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Logical shard count `M` (the unit of dispatch and retry).
    pub shards: u32,
    /// Seed for the partition permutation.
    pub seed: u64,
    /// Per-attempt straggler timeout; `None` waits indefinitely.
    pub shard_timeout: Option<Duration>,
    /// Re-dispatch attempts per shard after the first (death/straggle).
    pub max_retries: u32,
    /// Compute threads for the coordinator's own merge pass.
    pub merge_threads: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self { shards: 8, seed: 0, shard_timeout: None, max_retries: 2, merge_threads: 2 }
    }
}

/// What a cluster summarize run returns — the same summary the local
/// single-process pipeline would produce, plus fan-out accounting.
#[derive(Debug, Clone)]
pub struct ClusterResponse {
    /// Selected elements (global indices, selection order).
    pub summary: Vec<usize>,
    pub value: f64,
    /// Ground-set size in.
    pub n: usize,
    /// Survivor-union size after the per-shard prunes.
    pub union: usize,
    /// Survivors of the coordinator's final SS pass over the union.
    pub final_reduced: usize,
    /// Total SS rounds across all shard prunes.
    pub shard_rounds: u64,
    /// SS rounds of the final merge pass.
    pub merge_rounds: usize,
    /// Shard attempts re-dispatched (death + straggler).
    pub retries: u64,
    pub wall_s: f64,
}

/// One worker's health snapshot, as reported over the wire.
#[derive(Debug, Clone)]
pub struct WorkerHealth {
    pub worker: usize,
    pub jobs_done: u64,
    pub busy: u32,
    pub metrics_json: String,
}

/// Coordinator-side state for one worker connection. The reader thread
/// resolves `pending` responders; everything else only writes frames.
struct WorkerHandle {
    writer: Mutex<FrameWriter>,
    pending: Arc<Mutex<HashMap<u64, Responder<Message>>>>,
    alive: Arc<AtomicBool>,
    scope: Arc<Metrics>,
    reader: Option<JoinHandle<()>>,
}

pub struct ClusterCoordinator {
    cfg: ClusterConfig,
    workers: Vec<WorkerHandle>,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    pool: Arc<ThreadPool>,
}

/// Per-shard SS seed: mixes the request seed with the *logical* shard
/// index (splitmix-style odd constant), so shard pruning is independent
/// of which worker runs the shard — or how many workers exist.
fn shard_seed(base: u64, shard: u32) -> u64 {
    base ^ (shard as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

impl From<WireError> for ServiceError {
    fn from(e: WireError) -> Self {
        ServiceError::Rejected { reason: format!("wire: {e}") }
    }
}

impl ClusterCoordinator {
    /// Handshake every transport (`Hello` → `HelloAck`) and spawn its
    /// reader thread. Transport order defines worker indices.
    pub fn connect(
        transports: Vec<Box<dyn Transport>>,
        cfg: ClusterConfig,
    ) -> Result<Self, WireError> {
        if transports.is_empty() {
            return Err(WireError::Io("a cluster needs at least one worker".into()));
        }
        let metrics = Arc::new(Metrics::scoped("cluster"));
        let pool = Arc::new(ThreadPool::new(cfg.merge_threads.max(1), 64));
        let mut workers = Vec::with_capacity(transports.len());
        for (i, t) in transports.into_iter().enumerate() {
            workers.push(Self::handshake(i, t)?);
        }
        Ok(Self { cfg, workers, next_id: AtomicU64::new(1), metrics, pool })
    }

    fn handshake(index: usize, transport: Box<dyn Transport>) -> Result<WorkerHandle, WireError> {
        let scope = Arc::new(Metrics::scoped(&format!("cluster-worker-{index}")));
        let (r, w) = transport.split();
        let mut writer = FrameWriter::new(w);
        let mut reader = FrameReader::new(r);
        let bytes =
            writer.send(&Message::Hello { version: PROTO_VERSION, peer_id: index as u64 })?;
        scope.add(&scope.counters.rpc_frames_sent, 1);
        scope.add(&scope.counters.rpc_bytes_sent, bytes as u64);
        match reader.recv()? {
            Some((Message::HelloAck { version, .. }, bytes)) => {
                scope.add(&scope.counters.rpc_frames_recv, 1);
                scope.add(&scope.counters.rpc_bytes_recv, bytes as u64);
                if version != PROTO_VERSION {
                    return Err(WireError::Version { ours: PROTO_VERSION, theirs: version });
                }
            }
            Some((Message::ErrorMsg { err, .. }, _)) => {
                return Err(WireError::Io(format!("worker {index} refused handshake: {err}")))
            }
            Some((other, _)) => {
                return Err(WireError::Corrupt(format!(
                    "expected HelloAck, got tag {}",
                    other.tag()
                )))
            }
            None => return Err(WireError::Closed),
        }

        let pending: Arc<Mutex<HashMap<u64, Responder<Message>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let alive = Arc::new(AtomicBool::new(true));
        let reader_handle = {
            let pending = Arc::clone(&pending);
            let alive = Arc::clone(&alive);
            let scope = Arc::clone(&scope);
            std::thread::Builder::new()
                .name(format!("ss-cluster-rd-{index}"))
                .spawn(move || reader_main(reader, &pending, &alive, &scope))
                .expect("spawn cluster reader")
        };
        Ok(WorkerHandle {
            writer: Mutex::new(writer),
            pending,
            alive,
            scope,
            reader: Some(reader_handle),
        })
    }

    /// The `"cluster"` scope: merge-pass compute and request totals.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Per-connection scopes, indexed like the transports passed to
    /// [`connect`](Self::connect).
    pub fn worker_scopes(&self) -> Vec<Arc<Metrics>> {
        self.workers.iter().map(|w| Arc::clone(&w.scope)).collect()
    }

    /// Workers still considered live.
    pub fn live_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.alive.load(Ordering::SeqCst)).count()
    }

    /// Distributed summarize with default [`JobOptions`].
    pub fn summarize(
        &self,
        spec: ObjectiveSpec,
        rows: &FeatureMatrix,
        k: usize,
        params: &SsParams,
    ) -> Result<ClusterResponse, ServiceError> {
        self.summarize_with(spec, rows, k, params, JobOptions::default())
    }

    /// Distributed summarize: logical-shard fan-out, survivor union, one
    /// final SS + maximizer pass. See the module docs for the
    /// determinism and failure contracts.
    pub fn summarize_with(
        &self,
        spec: ObjectiveSpec,
        rows: &FeatureMatrix,
        k: usize,
        params: &SsParams,
        opts: JobOptions,
    ) -> Result<ClusterResponse, ServiceError> {
        let timer = Timer::new();
        let n = rows.n();
        let m = self.cfg.shards.max(1) as usize;
        self.metrics.add(&self.metrics.counters.requests, 1);
        self.metrics.add(&self.metrics.counters.items_in, n as u64);

        // seed-deterministic logical partition (matches the in-process
        // reference in examples/distributed_coreset.rs): shuffle, stride,
        // sort each shard ascending
        let mut perm: Vec<usize> = (0..n).collect();
        Rng::new(self.cfg.seed).shuffle(&mut perm);
        let shards: Vec<Vec<usize>> = (0..m)
            .map(|s| {
                let mut part: Vec<usize> = perm.iter().copied().skip(s).step_by(m).collect();
                part.sort_unstable();
                part
            })
            .collect();

        let survivors = self.fan_out(rows, &shards, spec, params, &opts)?;
        let shard_rounds: u64 = survivors.iter().map(|s| s.rounds as u64).sum();
        let retries = survivors.iter().map(|s| s.retries).sum();

        // union of disjoint shard cores, ascending — independent of
        // dispatch order, worker count, and retry history
        let mut union: Vec<usize> =
            survivors.iter().flat_map(|s| s.kept.iter().map(|&id| id as usize)).collect();
        union.sort_unstable();

        // final SS + maximizer over the union, under the request seed
        let merge_span = self.metrics.tracer().start();
        let f = spec.build(rows.clone());
        let backend = ShardedBackend::new(
            Arc::clone(&f),
            Arc::clone(&self.pool),
            Compute::Cpu,
            Arc::clone(&self.metrics),
        )
        .map_err(|e| ServiceError::Rejected { reason: e.to_string() })?;
        let deadline = opts.deadline;
        let mut check = move || match deadline {
            Some(d) if Instant::now() >= d => Some(Interrupt::DeadlineExceeded),
            _ => None,
        };
        let ss = sparsify_candidates_traced(
            &backend,
            &union,
            params,
            &mut check,
            self.metrics.tracer(),
        )
        .map_err(|e| self.fail(ServiceError::from(e)))?;
        let sol = MaximizerEngine::new(f.as_submodular(), GainRoute::Backend(&backend))
            .with_tracer(self.metrics.tracer())
            .lazy_greedy_with(&ss.kept, k, &mut check)
            .map_err(|e| self.fail(ServiceError::from(e)))?;
        self.metrics.tracer().record_since(
            EventKind::Merge,
            merge_span,
            union.len() as u64,
            ss.kept.len() as u64,
            k as u64,
            ss.rounds as u64,
        );
        self.metrics
            .add(&self.metrics.counters.items_pruned, (n - ss.kept.len()) as u64);
        self.metrics.add(&self.metrics.counters.completed, 1);

        Ok(ClusterResponse {
            summary: sol.set,
            value: sol.value,
            n,
            union: union.len(),
            final_reduced: ss.kept.len(),
            shard_rounds,
            merge_rounds: ss.rounds,
            retries,
            wall_s: timer.elapsed_s(),
        })
    }

    fn fail(&self, e: ServiceError) -> ServiceError {
        match &e {
            ServiceError::Cancelled => self.metrics.add(&self.metrics.counters.cancelled, 1),
            ServiceError::DeadlineExceeded => {
                self.metrics.add(&self.metrics.counters.deadline_exceeded, 1)
            }
            _ => self.metrics.add(&self.metrics.counters.failed, 1),
        }
        e
    }

    /// Dispatch every logical shard, resharding failures and stragglers
    /// onto surviving workers, until all shard cores are in.
    fn fan_out(
        &self,
        rows: &FeatureMatrix,
        shards: &[Vec<usize>],
        spec: ObjectiveSpec,
        params: &SsParams,
        opts: &JobOptions,
    ) -> Result<Vec<ShardOutcome>, ServiceError> {
        struct InFlight {
            shard: usize,
            worker: usize,
            ticket: Ticket<Message>,
            attempt: u32,
            started: Instant,
            job: u64,
            dispatch_span: u64,
        }

        let m = shards.len();
        let mut results: Vec<Option<ShardOutcome>> = (0..m).map(|_| None).collect();
        let mut queue: VecDeque<(usize, u32)> = (0..m).map(|s| (s, 0)).collect();
        let mut inflight: Vec<InFlight> = Vec::new();
        let mut done = 0usize;
        let mut rr = 0usize; // round-robin cursor over live workers

        while done < m {
            // check the request deadline before dispatching more work
            if let Some(d) = opts.deadline {
                if Instant::now() >= d {
                    for fl in &inflight {
                        self.send_best_effort(fl.worker, &Message::Cancel { job: fl.job });
                    }
                    return Err(self.fail(ServiceError::DeadlineExceeded));
                }
            }

            // dispatch everything queued onto live workers, round-robin
            while let Some((shard, attempt)) = queue.pop_front() {
                let Some(worker) = self.next_live(&mut rr) else {
                    queue.push_front((shard, attempt));
                    return Err(self.fail(ServiceError::Rejected {
                        reason: format!(
                            "no live workers left ({} shards unfinished)",
                            m - done
                        ),
                    }));
                };
                let job = self.next_id.fetch_add(1, Ordering::SeqCst);
                let ids: Vec<u64> = shards[shard].iter().map(|&i| i as u64).collect();
                let assign = Message::ShardAssign {
                    job,
                    shard: shard as u32,
                    spec,
                    params: SsParams {
                        seed: shard_seed(params.seed, shard as u32),
                        ..params.clone()
                    },
                    ids,
                    rows: rows.gather(&shards[shard]),
                };
                let (ticket, responder) = job_channel(JobOptions::default());
                self.workers[worker]
                    .pending
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .insert(job, responder);
                let dispatch_span = self.workers[worker].scope.tracer().start();
                match self.send_frame(worker, &assign) {
                    Ok(()) => {
                        self.metrics.add(&self.metrics.counters.shards_dispatched, 1);
                        inflight.push(InFlight {
                            shard,
                            worker,
                            ticket,
                            attempt,
                            started: Instant::now(),
                            job,
                            dispatch_span,
                        });
                    }
                    Err(_) => {
                        // send failure = worker death; responder drop
                        // resolves the ticket, we just requeue directly
                        self.workers[worker]
                            .pending
                            .lock()
                            .unwrap_or_else(|p| p.into_inner())
                            .remove(&job);
                        queue.push_front((shard, attempt));
                    }
                }
            }

            // poll in-flight shards without blocking the dispatch loop
            let mut progressed = false;
            let mut still: Vec<InFlight> = Vec::with_capacity(inflight.len());
            for mut fl in inflight {
                match fl.ticket.try_wait() {
                    Some(Ok(Message::ShardCore { kept, rounds, .. })) => {
                        progressed = true;
                        done += 1;
                        let scope = &self.workers[fl.worker].scope;
                        scope.tracer().record_since(
                            EventKind::ShardPrune,
                            fl.dispatch_span,
                            fl.shard as u64,
                            shards[fl.shard].len() as u64,
                            kept.len() as u64,
                            rounds as u64,
                        );
                        results[fl.shard] = Some(ShardOutcome {
                            kept,
                            rounds,
                            retries: fl.attempt as u64,
                        });
                    }
                    Some(Ok(other)) => {
                        // a worker answering a shard with anything else is
                        // protocol corruption: drop it, reshard
                        progressed = true;
                        self.kill_worker(fl.worker, &format!(
                            "unexpected reply tag {} for a shard",
                            other.tag()
                        ));
                        self.requeue(&mut queue, fl.shard, fl.attempt)?;
                    }
                    Some(Err(e)) => {
                        progressed = true;
                        // worker death resolves ServiceDown (dropped
                        // responder); worker-side typed errors arrive as
                        // themselves. Non-retryable service answers
                        // (Rejected) fail fast; transport-ish ones reshard.
                        if matches!(e, ServiceError::Rejected { .. }) {
                            return Err(self.fail(e));
                        }
                        self.requeue(&mut queue, fl.shard, fl.attempt)?;
                    }
                    None => {
                        // straggler check
                        if let Some(t) = self.cfg.shard_timeout {
                            if fl.started.elapsed() > t {
                                progressed = true;
                                self.send_best_effort(fl.worker, &Message::Cancel { job: fl.job });
                                self.workers[fl.worker]
                                    .pending
                                    .lock()
                                    .unwrap_or_else(|p| p.into_inner())
                                    .remove(&fl.job);
                                self.requeue(&mut queue, fl.shard, fl.attempt)?;
                                continue;
                            }
                        }
                        still.push(fl);
                    }
                }
            }
            inflight = still;
            if !progressed && queue.is_empty() && done < m {
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        Ok(results.into_iter().map(|r| r.expect("all shards resolved")).collect())
    }

    /// Requeue a failed shard attempt, enforcing the retry bound.
    fn requeue(
        &self,
        queue: &mut VecDeque<(usize, u32)>,
        shard: usize,
        attempt: u32,
    ) -> Result<(), ServiceError> {
        if attempt >= self.cfg.max_retries {
            return Err(self.fail(ServiceError::Rejected {
                reason: format!(
                    "shard {shard} failed after {} attempts",
                    attempt as u64 + 1
                ),
            }));
        }
        self.metrics.add(&self.metrics.counters.shard_retries, 1);
        queue.push_back((shard, attempt + 1));
        Ok(())
    }

    /// Next live worker after the round-robin cursor, if any.
    fn next_live(&self, rr: &mut usize) -> Option<usize> {
        for _ in 0..self.workers.len() {
            let idx = *rr % self.workers.len();
            *rr += 1;
            if self.workers[idx].alive.load(Ordering::SeqCst) {
                return Some(idx);
            }
        }
        None
    }

    fn send_frame(&self, worker: usize, msg: &Message) -> Result<(), WireError> {
        let w = &self.workers[worker];
        let mut fw = w.writer.lock().unwrap_or_else(|p| p.into_inner());
        match fw.send(msg) {
            Ok(bytes) => {
                w.scope.add(&w.scope.counters.rpc_frames_sent, 1);
                w.scope.add(&w.scope.counters.rpc_bytes_sent, bytes as u64);
                w.scope.tracer().record_now(
                    EventKind::RpcSend,
                    msg.tag() as u64,
                    bytes as u64,
                    0,
                    0,
                );
                Ok(())
            }
            Err(e) => {
                drop(fw);
                self.kill_worker(worker, &e.to_string());
                Err(e)
            }
        }
    }

    fn send_best_effort(&self, worker: usize, msg: &Message) {
        let _ = self.send_frame(worker, msg);
    }

    /// Mark a worker dead and fail its pending jobs (dropping the
    /// responders resolves their tickets `ServiceDown`).
    fn kill_worker(&self, worker: usize, _why: &str) {
        let w = &self.workers[worker];
        if w.alive.swap(false, Ordering::SeqCst) {
            self.metrics.add(&self.metrics.counters.worker_deaths, 1);
        }
        w.pending.lock().unwrap_or_else(|p| p.into_inner()).clear();
    }

    /// Probe every live worker; `None` for workers that are dead or
    /// don't answer within `timeout`.
    pub fn health(&self, timeout: Duration) -> Vec<Option<WorkerHealth>> {
        let mut out = Vec::with_capacity(self.workers.len());
        for i in 0..self.workers.len() {
            if !self.workers[i].alive.load(Ordering::SeqCst) {
                out.push(None);
                continue;
            }
            let nonce = self.next_id.fetch_add(1, Ordering::SeqCst);
            let (mut ticket, responder) = job_channel::<Message>(JobOptions::default());
            self.workers[i]
                .pending
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .insert(nonce, responder);
            if self.send_frame(i, &Message::HealthProbe { nonce }).is_err() {
                out.push(None);
                continue;
            }
            match ticket.wait_timeout(timeout) {
                Some(Ok(Message::HealthSnap { jobs_done, busy, metrics_json, .. })) => {
                    out.push(Some(WorkerHealth { worker: i, jobs_done, busy, metrics_json }));
                }
                _ => {
                    self.workers[i]
                        .pending
                        .lock()
                        .unwrap_or_else(|p| p.into_inner())
                        .remove(&nonce);
                    out.push(None);
                }
            }
        }
        out
    }

}

/// What one logical shard contributed once its prune (finally) landed.
struct ShardOutcome {
    kept: Vec<u64>,
    rounds: u32,
    retries: u64,
}

impl Drop for ClusterCoordinator {
    fn drop(&mut self) {
        for i in 0..self.workers.len() {
            if self.workers[i].alive.load(Ordering::SeqCst) {
                self.send_best_effort(i, &Message::Shutdown);
            }
        }
        // the worker answers Shutdown by closing its half of the
        // connection, which ends each reader thread at EOF
        for w in &mut self.workers {
            if let Some(h) = w.reader.take() {
                let _ = h.join();
            }
        }
    }
}

/// Reader loop for one worker connection: resolve pending tickets, meter
/// traffic, and on any stream failure mark the worker dead and fail its
/// pending jobs (dropping responders → `ServiceDown` → reshard).
fn reader_main(
    mut reader: FrameReader,
    pending: &Mutex<HashMap<u64, Responder<Message>>>,
    alive: &AtomicBool,
    scope: &Metrics,
) {
    loop {
        match reader.recv() {
            Ok(Some((msg, bytes))) => {
                scope.add(&scope.counters.rpc_frames_recv, 1);
                scope.add(&scope.counters.rpc_bytes_recv, bytes as u64);
                let (job, shard) = match &msg {
                    Message::ShardCore { job, shard, .. } => (*job, *shard as u64),
                    Message::SummarizeResp { job, .. } | Message::ErrorMsg { job, .. } => {
                        (*job, 0)
                    }
                    Message::HealthSnap { nonce, .. } => (*nonce, 0),
                    _ => (0, 0),
                };
                scope.tracer().record_now(
                    EventKind::RpcRecv,
                    msg.tag() as u64,
                    bytes as u64,
                    job,
                    shard,
                );
                match msg {
                    Message::ShardCore { .. }
                    | Message::SummarizeResp { .. }
                    | Message::HealthSnap { .. } => {
                        if let Some(r) =
                            pending.lock().unwrap_or_else(|p| p.into_inner()).remove(&job)
                        {
                            r.resolve(Ok(msg));
                        }
                    }
                    Message::ErrorMsg { job: j, err } => {
                        if j == 0 {
                            // connection-level error: the worker is telling
                            // us its end is being torn down
                            mark_dead(alive, scope);
                            pending.lock().unwrap_or_else(|p| p.into_inner()).clear();
                            return;
                        }
                        if let Some(r) =
                            pending.lock().unwrap_or_else(|p| p.into_inner()).remove(&j)
                        {
                            r.resolve(Err(err));
                        }
                    }
                    _ => { /* protocol chatter we don't track */ }
                }
            }
            Ok(None) => {
                mark_dead(alive, scope);
                pending.lock().unwrap_or_else(|p| p.into_inner()).clear();
                return;
            }
            Err(_) => {
                scope.add(&scope.counters.wire_decode_errors, 1);
                mark_dead(alive, scope);
                pending.lock().unwrap_or_else(|p| p.into_inner()).clear();
                return;
            }
        }
    }
}

/// Reader-side death: count it on the connection's scope, but only if the
/// send path ([`ClusterCoordinator::kill_worker`]) didn't get there first —
/// both guard on the same `alive` swap, so every death is counted exactly
/// once across the two scopes.
fn mark_dead(alive: &AtomicBool, scope: &Metrics) {
    if alive.swap(false, Ordering::SeqCst) {
        scope.add(&scope.counters.worker_deaths, 1);
    }
}
