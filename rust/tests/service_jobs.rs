//! Job-API contract tests for the service surface: ticket semantics
//! (timed waits never lose responses, cancel-after-completion is a no-op),
//! shed semantics (expired/cancelled queued jobs never touch the compute
//! pool), the copy-on-snapshot concurrency guarantee (appends proceed
//! while a Final snapshot job is in flight, and the job's summary is
//! bit-identical to a quiesced in-place snapshot), and the close/append
//! race (rows are either counted in close's stats or typed-rejected —
//! never silently landed on a closed session).

use std::sync::Arc;
use std::time::Duration;

use submodular_ss::algorithms::SsParams;
use submodular_ss::coordinator::{
    JobOptions, Metrics, ServiceConfig, ServiceError, SummarizationService, SummarizeRequest,
};
use submodular_ss::stream::{SnapshotMode, StreamConfig, StreamSession};
use submodular_ss::submodular::Concave;
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;
use submodular_ss::ObjectiveSpec;

fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
        }
    }
    m
}

fn req(n: usize, seed: u64) -> SummarizeRequest {
    SummarizeRequest::features(feats(n, 16, seed), 8, SsParams::default().with_seed(seed))
}

/// A request big enough to hold a single worker busy for a while (the
/// "slow job" the queued-behind tests hide behind).
fn slow_req(seed: u64) -> SummarizeRequest {
    req(1400, seed)
}

#[test]
fn wait_timeout_never_loses_a_late_response() {
    // one worker: job B sits queued behind slow job A, so B's short timed
    // wait expires — and the eventual response must still arrive intact
    let svc = SummarizationService::start(
        ServiceConfig { workers: 1, queue_depth: 8, compute_threads: 2 },
        None,
    );
    let a = svc.submit(slow_req(1));
    let mut b = svc.submit(req(200, 2));
    // a zero-length timed wait expires immediately; B cannot possibly have
    // resolved (the lone worker must first finish A's full SS pass), so
    // this exercises the expiry path without a hardware-speed assumption
    assert!(
        b.wait_timeout(Duration::ZERO).is_none(),
        "B is queued behind A; a zero-length wait must time out"
    );
    assert!(b.try_wait().is_none(), "still queued");
    let resp = b.wait().expect("late response must not be lost by the expired waits");
    assert_eq!(resp.n, 200);
    assert_eq!(resp.summary.len(), 8);
    a.wait().unwrap();
}

#[test]
fn cancel_after_completion_is_a_noop() {
    let svc = SummarizationService::start(ServiceConfig::default(), None);
    let ticket = svc.submit(req(150, 3));
    while !ticket.is_done() {
        std::thread::sleep(Duration::from_millis(1));
    }
    ticket.cancel();
    let resp = ticket.wait().expect("cancel after completion must not clobber the result");
    assert_eq!(resp.n, 150);
    assert_eq!(
        svc.metrics().snapshot().get("cancelled").unwrap().as_f64(),
        Some(0.0),
        "a post-completion cancel is not a shed"
    );
}

#[test]
fn deadline_expired_queued_jobs_are_shed_without_compute() {
    let svc = SummarizationService::start(
        ServiceConfig { workers: 1, queue_depth: 16, compute_threads: 1 },
        None,
    );
    // already-expired deadlines: the dequeue check sheds every one of
    // these before the objective is even materialized
    let tickets: Vec<_> = (0..3)
        .map(|i| svc.submit_with(req(400, 10 + i), JobOptions::default().with_timeout(Duration::ZERO)))
        .collect();
    for t in tickets {
        match t.wait() {
            Err(ServiceError::DeadlineExceeded) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }
    let m = svc.metrics().snapshot();
    let get = |k: &str| m.get(k).unwrap().as_f64().unwrap();
    assert_eq!(get("deadline_exceeded"), 3.0);
    assert_eq!(get("requests"), 3.0, "shed jobs were still accepted");
    assert_eq!(get("completed"), 0.0);
    assert_eq!(get("failed"), 0.0, "a deadline shed is not a failure");
    assert_eq!(get("items_in"), 0.0, "shed jobs must never reach the pipeline");
    assert_eq!(get("divergence_evals"), 0.0, "shed jobs must never touch the compute pool");
}

#[test]
fn cancelled_queued_job_is_shed_and_metered() {
    let svc = SummarizationService::start(
        ServiceConfig { workers: 1, queue_depth: 8, compute_threads: 2 },
        None,
    );
    let slow = svc.submit(slow_req(4));
    let victim = svc.submit(req(400, 5));
    victim.cancel();
    match victim.wait() {
        Err(ServiceError::Cancelled) => {}
        other => panic!("expected Cancelled, got {other:?}"),
    }
    slow.wait().unwrap();
    let m = svc.metrics().snapshot();
    assert_eq!(m.get("cancelled").unwrap().as_f64(), Some(1.0));
    assert_eq!(m.get("completed").unwrap().as_f64(), Some(1.0));
    // only the slow job's ground set entered the pipeline
    assert_eq!(m.get("items_in").unwrap().as_f64(), Some(1400.0));
}

#[test]
fn deadline_mid_run_aborts_at_a_round_boundary() {
    // a 1ms deadline on a large request: on any realistic hardware the job
    // expires in the queue or mid-SS-pass and resolves DeadlineExceeded
    // with exactly one metered shed. Deadlines are cooperative (checked at
    // dequeue and round boundaries only), so a machine that provably beats
    // the deadline is a legitimate outcome, not a failure — the
    // deterministic round-boundary abort itself is pinned at the algorithm
    // level (`ss::tests::interrupt_probe_aborts_between_rounds`) and the
    // guaranteed-expired dequeue shed by the test above.
    let svc = SummarizationService::start(
        ServiceConfig { workers: 1, queue_depth: 4, compute_threads: 2 },
        None,
    );
    let t =
        svc.submit_with(req(3000, 6), JobOptions::default().with_timeout(Duration::from_millis(1)));
    match t.wait() {
        Err(ServiceError::DeadlineExceeded) => {
            assert_eq!(
                svc.metrics().snapshot().get("deadline_exceeded").unwrap().as_f64(),
                Some(1.0)
            );
        }
        Ok(resp) => {
            // the whole pipeline finished inside 1ms: nothing may be shed
            assert_eq!(resp.n, 3000);
            assert_eq!(
                svc.metrics().snapshot().get("deadline_exceeded").unwrap().as_f64(),
                Some(0.0)
            );
        }
        other => panic!("expected DeadlineExceeded (or a sub-1ms completion), got {other:?}"),
    }
}

#[test]
fn cancel_mid_run_lands_within_one_greedy_cohort() {
    // the interrupt probe is now polled inside the maximizer's epoch loop
    // too, so a cancel that arrives after the SS pass finishes no longer
    // waits out the whole greedy run — its latency is bounded by one
    // cohort dispatch. Cancels are cooperative and inherently racy at this
    // level: a job that beats the cancel legitimately resolves Ok; the
    // deterministic round-boundary abort is pinned at the engine level
    // (`engine::tests::interrupt_probe_lands_at_a_round_boundary`).
    let svc = SummarizationService::start(
        ServiceConfig { workers: 1, queue_depth: 4, compute_threads: 2 },
        None,
    );
    let t = svc.submit(slow_req(8));
    std::thread::sleep(Duration::from_millis(2));
    t.cancel();
    match t.wait() {
        Err(ServiceError::Cancelled) => {
            assert_eq!(svc.metrics().snapshot().get("cancelled").unwrap().as_f64(), Some(1.0));
        }
        Ok(resp) => {
            // completed before the cancel landed: nothing may be shed
            assert_eq!(resp.n, 1400);
            assert_eq!(svc.metrics().snapshot().get("cancelled").unwrap().as_f64(), Some(0.0));
        }
        other => panic!("expected Cancelled (or a completion that beat it), got {other:?}"),
    }
}

#[test]
fn appends_proceed_during_inflight_final_snapshot() {
    let d = 12usize;
    let k = 6usize;
    let seed = 7u64;
    let base = feats(500, d, 70);
    let extra = feats(300, d, 71);
    let cfg = || StreamConfig::new(k).with_ss(SsParams::default().with_seed(seed));

    // quiesced twin session: the old lock-holding in-place snapshot is the
    // bit-identity oracle for the job's summary
    let mut twin = StreamSession::new(
        ObjectiveSpec::Features(Concave::Sqrt),
        d,
        cfg(),
        Arc::new(ThreadPool::new(2, 64)),
        Arc::new(Metrics::new()),
    )
    .unwrap();
    twin.append(base.data()).unwrap();
    let expected = twin.snapshot_summary(SnapshotMode::Final).unwrap();

    // one worker, occupied by a slow batch job → the snapshot job is
    // accepted but cannot run yet; appends must land regardless
    let svc = SummarizationService::start(
        ServiceConfig { workers: 1, queue_depth: 8, compute_threads: 2 },
        None,
    );
    let id = svc.open_stream(ObjectiveSpec::Features(Concave::Sqrt), d, cfg()).unwrap();
    svc.append(id, base.data()).unwrap();
    let blocker = svc.submit(slow_req(8));
    let snap_ticket = svc.submit_snapshot(id, SnapshotMode::Final).unwrap();
    let in_flight_at_submit = !snap_ticket.is_done();

    // appends while the snapshot job is in flight
    for chunk in extra.data().chunks(d * 60) {
        let r = svc.append(id, chunk).unwrap();
        assert!(r.appended > 0);
    }
    assert!(
        in_flight_at_submit,
        "snapshot job must have been queued behind the blocker when appends began"
    );
    let total_live_now = 800; // 500 + 300, full window (no eviction)

    let snap = snap_ticket.wait().unwrap();
    blocker.wait().unwrap();
    // copy-on-snapshot: the job describes the stream as of submit time...
    assert_eq!(snap.live, 500, "snapshot must reflect the pre-append clone");
    // ...and is bit-identical to the quiesced in-place snapshot
    assert_eq!(snap.summary, expected.summary);
    assert_eq!(snap.value.to_bits(), expected.value.to_bits());
    assert_eq!(snap.ss_rounds, expected.ss_rounds);
    // the session kept every appended row meanwhile
    let stats = svc.close(id).unwrap();
    assert_eq!(stats.appends, total_live_now as u64);
    assert_eq!(stats.live, total_live_now);
}

#[test]
fn snapshot_job_can_be_cancelled() {
    let svc = SummarizationService::start(
        ServiceConfig { workers: 1, queue_depth: 8, compute_threads: 2 },
        None,
    );
    let id = svc
        .open_stream(
            ObjectiveSpec::Features(Concave::Sqrt),
            10,
            StreamConfig::new(5).with_ss(SsParams::default().with_seed(9)),
        )
        .unwrap();
    svc.append(id, feats(600, 10, 90).data()).unwrap();
    let blocker = svc.submit(slow_req(10));
    let victim = svc.submit_snapshot(id, SnapshotMode::Final).unwrap();
    victim.cancel();
    match victim.wait() {
        Err(ServiceError::Cancelled) => {}
        other => panic!("expected Cancelled snapshot, got {other:?}"),
    }
    blocker.wait().unwrap();
    // the stream itself is unaffected by the shed job
    let snap = svc.submit_snapshot(id, SnapshotMode::Final).unwrap().wait().unwrap();
    assert_eq!(snap.summary.len(), 5);
    assert_eq!(snap.live, 600);
}

#[test]
fn close_racing_slow_append_never_loses_rows() {
    // an appender hammers the stream while the main thread closes it: every
    // append that returned Ok must be visible in close()'s stats, and every
    // append after the close must shed with a typed error — no third
    // outcome (rows silently landing on a closed session) may exist
    let d = 8usize;
    let svc = Arc::new(SummarizationService::start(ServiceConfig::default(), None));
    let id = svc
        .open_stream(
            ObjectiveSpec::Features(Concave::Sqrt),
            d,
            StreamConfig::new(4)
                .with_ss(SsParams::default().with_seed(13))
                .with_high_water(400),
        )
        .unwrap();
    let batch = feats(200, d, 77);
    let appender = {
        let svc = Arc::clone(&svc);
        let batch = batch.data().to_vec();
        std::thread::spawn(move || {
            let mut ok_rows = 0u64;
            loop {
                match svc.append(id, &batch) {
                    Ok(r) => ok_rows += r.appended as u64,
                    Err(ServiceError::ServiceDown) | Err(ServiceError::UnknownStream(_)) => {
                        return ok_rows;
                    }
                    Err(other) => panic!("unexpected append error mid-race: {other:?}"),
                }
            }
        })
    };
    // let the appender land a few batches, then close mid-flight
    std::thread::sleep(Duration::from_millis(30));
    let stats = svc.close(id).unwrap();
    let ok_rows = appender.join().unwrap();
    assert!(ok_rows > 0, "appender must have landed something before the close");
    assert_eq!(
        stats.appends, ok_rows,
        "every Ok append must be counted by close; every uncounted append must have shed"
    );
    // the id stays dead afterwards
    match svc.append(id, batch.data()) {
        Err(ServiceError::UnknownStream(_)) => {}
        other => panic!("post-close append must be UnknownStream, got {other:?}"),
    }
}
