//! Integration: full pipelines over the synthetic substrates — news days,
//! DUC topics, videos — exercising data generation → features → algorithms
//! → metrics end to end (CPU path; the PJRT path is covered by
//! pjrt_parity.rs and service_demo).

use submodular_ss::algorithms::{SieveParams, SsParams};
use submodular_ss::data::video::VideoParams;
use submodular_ss::data::{CorpusParams, NewsGenerator};
use submodular_ss::eval::news::run_days;
use submodular_ss::eval::runners::{rouge_of, run_trio, TrioParams};
use submodular_ss::eval::video_eval::run_video;
use submodular_ss::submodular::FeatureBased;

#[test]
fn news_pipeline_shapes_match_paper() {
    let records = run_days(6, 300, 1200, 42);
    // (a) SS rel utility high on every day
    for r in &records {
        assert!(
            r.results[2].rel_utility > 0.9,
            "day n={}: ss rel {}",
            r.n,
            r.results[2].rel_utility
        );
        // (b) sieve below lazy greedy
        assert!(r.results[1].value <= r.results[0].value + 1e-9);
        // (c) SS working set much smaller than n
        assert!(r.vprime * 2 < r.n, "|V'|={} vs n={}", r.vprime, r.n);
    }
    // (d) median sieve rel-utility below median SS rel-utility (Fig 3 shape)
    let mut sieve: Vec<f64> = records.iter().map(|r| r.results[1].rel_utility).collect();
    let mut ss: Vec<f64> = records.iter().map(|r| r.results[2].rel_utility).collect();
    sieve.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ss.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert!(
        ss[ss.len() / 2] > sieve[sieve.len() / 2],
        "median SS rel {} must exceed sieve {}",
        ss[ss.len() / 2],
        sieve[sieve.len() / 2]
    );
}

#[test]
fn rouge_ordering_ss_vs_sieve_on_average() {
    // Fig 3's ROUGE claim, averaged over days (single days are noisy)
    let g = NewsGenerator::new(CorpusParams { vocab_size: 1500, ..Default::default() }, 7);
    let mut ss_sum = 0.0;
    let mut sieve_sum = 0.0;
    let days = 5;
    for i in 0..days {
        let day = g.day(600, 0, 100 + i);
        let f = FeatureBased::sqrt(day.feats.clone());
        let rs = run_trio(&f, &TrioParams::paper(day.k, i));
        sieve_sum += rouge_of(&rs[1].set, &day.sentences, &day.reference).recall;
        ss_sum += rouge_of(&rs[2].set, &day.sentences, &day.reference).recall;
    }
    assert!(
        ss_sum >= sieve_sum * 0.95,
        "avg SS ROUGE {} should be ≳ sieve {}",
        ss_sum / days as f64,
        sieve_sum / days as f64
    );
}

#[test]
fn video_pipeline_table2_shape() {
    // Table 2's shape: SS time < greedy time at video budgets (k = 15% of
    // frames), with |V'| a strict reduction. The paper's greedy baseline
    // behaves like an O(n·k)-evaluation (non-incremental) greedy, which our
    // naive greedy matches; our *lazy* greedy with an incremental oracle is
    // a stronger baseline than the paper's (see EXPERIMENTS.md §Deviations).
    let n = 1600;
    let rec = run_video("clip", n, &VideoParams { d: 128, ..Default::default() }, 5);
    let ss = &rec.results[2];
    assert!(ss.working_set < n);
    assert!(ss.rel_utility > 0.9, "ss rel {}", ss.rel_utility);
    let f = FeatureBased::sqrt(rec.video.feats.clone());
    let all: Vec<usize> = (0..n).collect();
    let k = (n as f64 * 0.15) as usize;
    let naive = submodular_ss::algorithms::greedy(&f, &all, k);
    assert!(
        ss.time_s < naive.wall_s,
        "at k=15%·n SS ({:.3}s) must beat O(n·k) greedy ({:.3}s) — Table 2's core claim",
        ss.time_s,
        naive.wall_s
    );
}

#[test]
fn sieve_memory_budget_respected() {
    // the paper's sieve runs hold 50k (news) / 10k (video) elements
    let g = NewsGenerator::new(CorpusParams::default(), 11);
    let day = g.day(400, 0, 11);
    let f = FeatureBased::sqrt(day.feats.clone());
    let all: Vec<usize> = (0..400).collect();
    let params = SieveParams::paper_default();
    let sol = submodular_ss::algorithms::sieve_streaming(&f, &all, day.k, &params);
    assert!(sol.set.len() <= day.k);
    assert_eq!(
        submodular_ss::algorithms::sieve_streaming::sieve_memory_elements(day.k, &params),
        50 * day.k
    );
}

#[test]
fn ss_seed_stability_across_substrates() {
    // same params + same data ⇒ identical summaries on both substrates
    let g = NewsGenerator::new(CorpusParams::default(), 13);
    let day = g.day(500, 0, 13);
    let f = FeatureBased::sqrt(day.feats.clone());
    let backend = submodular_ss::algorithms::CpuBackend::new(&f);
    let p = SsParams::default().with_seed(99);
    let a = submodular_ss::algorithms::sparsify(&backend, &p);
    let b = submodular_ss::algorithms::sparsify(&backend, &p);
    assert_eq!(a.kept, b.kept);

    let v1 = run_video("stable", 900, &VideoParams { d: 64, ..Default::default() }, 21);
    let v2 = run_video("stable", 900, &VideoParams { d: 64, ..Default::default() }, 21);
    assert_eq!(v1.results[2].set, v2.results[2].set);
}
