//! LSH-bucketed neighbor build ↔ exact builder contract tests.
//!
//! The bucketed builder is only allowed behind the construction seam
//! because of four properties, pinned here on **production paths**
//! (SS→greedy, the maximizer engine, the sharded backend) rather than on
//! index internals:
//!
//! 1. **Saturation exactness** — `bits = 0` puts every row in one bucket,
//!    so the candidate set is all pairs and the build is bit-identical to
//!    the exact all-pairs builder, serial and pooled alike.
//! 2. **Recall floor** — on clustered data a real multi-table index keeps
//!    ≥ 0.9 of the exact top-t similarity mass, and the end-to-end
//!    pipeline over the LSH-built objective keeps ≥ 0.95 of the
//!    exact-built pipeline's utility.
//! 3. **History-freedom** — incremental `append_row` through the live
//!    index reproduces a fresh LSH batch build bit-for-bit at any prefix.
//! 4. **Adaptive budget** — with auto `t`, rows in clusters that outgrow
//!    the fixed `O(log n)` budget keep enough neighbors to hold the
//!    utility floor the fixed budget drops (the EXPERIMENTS.md collapse).

use std::sync::atomic::Ordering;
use std::sync::Arc;

use submodular_ss::algorithms::{
    ss_then_greedy, CpuBackend, GainRoute, MaximizerEngine, SsParams,
};
use submodular_ss::coordinator::{Compute, Metrics, ShardedBackend};
use submodular_ss::submodular::{
    BatchedDivergence, BuildStrategy, FacilityLocation, SparseSimStore, SubmodularFn,
};
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

/// Signed rows: about half the pairwise cosines clamp to zero, so both
/// builders see genuinely absent entries, not just truncated ones.
fn rows(n: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = rng.f32() - 0.3;
        }
    }
    m
}

/// `clusters` tight groups (cluster center plus small noise): the regime
/// hyperplane LSH is built for — a row's informative neighbors share its
/// sign pattern almost surely.
fn clustered_rows(n: usize, clusters: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut centers = FeatureMatrix::zeros(clusters, d);
    for c in 0..clusters {
        for j in 0..d {
            centers.row_mut(c)[j] = rng.f32() * 2.0 - 1.0;
        }
    }
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        let c = i % clusters;
        for j in 0..d {
            m.row_mut(i)[j] = centers.row(c)[j] + 0.05 * (rng.f32() - 0.5);
        }
    }
    m
}

fn assert_stores_equal(a: &SparseSimStore, b: &SparseSimStore, ctx: &str) {
    let (na, ta, la, ca, va) = a.export_parts();
    let (nb, tb, lb, cb, vb) = b.export_parts();
    assert_eq!((na, ta), (nb, tb), "{ctx}: shape diverged");
    assert_eq!(la, lb, "{ctx}: row lengths diverged");
    assert_eq!(ca, cb, "{ctx}: neighbor columns diverged");
    assert_eq!(
        va.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        vb.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "{ctx}: neighbor values diverged"
    );
}

/// Off-diagonal similarity mass a store holds (the diagonal is pinned to
/// 1.0 in every row, so it cancels out of any recall ratio).
fn off_diagonal_mass(s: &SparseSimStore) -> f64 {
    let (n, _, _, _, vals) = s.export_parts();
    let mass: f64 = vals.iter().map(|&v| v as f64).sum();
    mass - n as f64
}

#[test]
fn saturated_lsh_is_bit_identical_to_exact_through_the_pipeline() {
    let d = 9;
    let n = 220;
    let k = 7;
    for seed in [3u64, 17] {
        let data = rows(n, d, seed);
        let exact =
            FacilityLocation::from_features_strat(&data, 0, Some(20), BuildStrategy::Exact, None);
        let lsh = FacilityLocation::from_features_strat(
            &data,
            0,
            Some(20),
            BuildStrategy::Lsh { tables: 1, bits: 0 },
            None,
        );
        assert_stores_equal(
            exact.sparse_store().unwrap(),
            lsh.sparse_store().unwrap(),
            &format!("seed {seed} serial"),
        );

        // the saturated build must also be exact when it fans over a pool
        for threads in [1usize, 3] {
            let pool = ThreadPool::new(threads, 16);
            let pooled = FacilityLocation::from_features_strat(
                &data,
                0,
                Some(20),
                BuildStrategy::Lsh { tables: 1, bits: 0 },
                Some((&pool, 2 * threads + 1)),
            );
            assert_stores_equal(
                exact.sparse_store().unwrap(),
                pooled.sparse_store().unwrap(),
                &format!("seed {seed} threads {threads}"),
            );
        }

        // and the full paper pipeline cannot tell the objectives apart
        let params = SsParams::default().with_seed(seed);
        let be = CpuBackend::new(&exact);
        let bl = CpuBackend::new(&lsh);
        let (ss_e, sol_e) = ss_then_greedy(&exact, &be, k, &params);
        let (ss_l, sol_l) = ss_then_greedy(&lsh, &bl, k, &params);
        assert_eq!(ss_e.kept, ss_l.kept, "seed {seed}: SS trajectories diverged");
        assert_eq!(sol_e.set, sol_l.set, "seed {seed}: greedy commits diverged");
        assert_eq!(sol_e.value.to_bits(), sol_l.value.to_bits());
    }
}

#[test]
fn multi_table_lsh_keeps_recall_and_the_utility_floor_on_clustered_data() {
    let n = 600;
    let d = 12;
    let k = 12;
    let t = 24;
    for seed in [7u64, 21] {
        let data = clustered_rows(n, k, d, seed);
        let exact =
            FacilityLocation::from_features_strat(&data, 0, Some(t), BuildStrategy::Exact, None);
        let lsh = FacilityLocation::from_features_strat(
            &data,
            0,
            Some(t),
            BuildStrategy::Lsh { tables: 8, bits: 4 },
            None,
        );

        // the index must actually prune: fewer candidates than all pairs
        let (cands, bmax) = lsh.sparse_store().unwrap().lsh_stats().unwrap();
        assert!(cands > 0 && (cands as usize) < n * (n - 1), "no pruning: {cands} candidates");
        assert!(bmax as usize <= n);

        // recall: the LSH top-t holds ≥ 0.9 of the exact top-t mass
        let exact_mass = off_diagonal_mass(exact.sparse_store().unwrap());
        let lsh_mass = off_diagonal_mass(lsh.sparse_store().unwrap());
        assert!(lsh_mass <= exact_mass + 1e-6, "LSH rows can only be a candidate subset");
        assert!(
            lsh_mass >= 0.9 * exact_mass,
            "seed {seed}: recall collapsed — LSH mass {lsh_mass:.2} vs exact {exact_mass:.2}"
        );

        // end to end, serial and sharded: the LSH-picked summary keeps
        // ≥ 0.95 of the exact-built pipeline's utility *under the exact
        // objective* (the only fair scorer)
        let params = SsParams::default().with_seed(seed);
        let be = CpuBackend::new(&exact);
        let (_, sol_e) = ss_then_greedy(&exact, &be, k, &params);
        let bl = CpuBackend::new(&lsh);
        let (_, sol_l) = ss_then_greedy(&lsh, &bl, k, &params);
        let rel = exact.eval(&sol_l.set) / sol_e.value;
        assert!(rel >= 0.95, "seed {seed}: serial rel-utility {rel:.4}");

        for threads in [1usize, 3] {
            let pool = Arc::new(ThreadPool::new(threads, 16));
            let f: Arc<dyn BatchedDivergence> = Arc::new(lsh.clone());
            let backend =
                ShardedBackend::new(f, Arc::clone(&pool), Compute::Cpu, Arc::new(Metrics::new()))
                    .unwrap();
            let (_, sol) = ss_then_greedy(&lsh, &backend, k, &params);
            let rel = exact.eval(&sol.set) / sol_e.value;
            assert!(rel >= 0.95, "seed {seed} threads {threads}: rel-utility {rel:.4}");
        }
    }
}

#[test]
fn incremental_append_matches_a_fresh_lsh_build_at_every_prefix() {
    let d = 8;
    let n = 160;
    let start = 40;
    let full = rows(n, d, 11);
    let build = |m: &FeatureMatrix| {
        FacilityLocation::from_features_strat(
            m,
            0,
            Some(12),
            BuildStrategy::Lsh { tables: 4, bits: 3 },
            None,
        )
    };
    let prefix: Vec<usize> = (0..start).collect();
    let mut grown = build(&full.gather(&prefix));
    let mut feats = full.gather(&prefix);
    let mut updates = 0u64;
    for m in start..n {
        feats.push_row(full.row(m));
        updates += grown
            .append_row_from_features(&feats)
            .expect("sparse store must take the append fast path");
        if m + 1 == 90 || m + 1 == n {
            let idx: Vec<usize> = (0..=m).collect();
            let fresh = build(&full.gather(&idx));
            assert_stores_equal(
                grown.sparse_store().unwrap(),
                fresh.sparse_store().unwrap(),
                &format!("prefix {}", m + 1),
            );
        }
    }
    assert!(updates > 0, "growing 4× must displace at least one border");
    // the grown index still has the builder's geometry
    assert_eq!(grown.sparse_store().unwrap().lsh_params(), Some((4, 3)));
}

#[test]
fn adaptive_budget_holds_the_floor_where_fixed_t_underfits_the_clusters() {
    // 5 clusters of 200 rows: cluster size far exceeds the fixed
    // auto_neighbors budget, the regime where the fixed-t store saturates
    // mid-cluster and greedy's gains go blind (the 0.81 collapse
    // EXPERIMENTS.md records). The adaptive cap (4× auto) spans a whole
    // cluster, so the LSH auto-t build must restore the ≥ 0.95 floor.
    let n = 1000;
    let clusters = 5;
    let d = 10;
    let k = 10;
    let data = clustered_rows(n, clusters, d, 13);
    let auto = FacilityLocation::auto_neighbors(n);
    assert!(auto < n / clusters, "collapse regime requires t < cluster size");

    let dense = FacilityLocation::from_features_dense(&data);
    let fixed =
        FacilityLocation::from_features_strat(&data, 0, None, BuildStrategy::Exact, None);
    let adaptive = FacilityLocation::from_features_strat(
        &data,
        0,
        None,
        BuildStrategy::Lsh { tables: 8, bits: 3 },
        None,
    );
    let store = adaptive.sparse_store().unwrap();
    assert_eq!(store.t(), (auto * 4).min(n - 1), "auto t must engage the 4× adaptive cap");
    assert_eq!(store.adapt_floor(), Some((auto / 2).max(8)));
    assert_eq!(fixed.sparse_store().unwrap().t(), auto);

    let cands: Vec<usize> = (0..n).collect();
    let run = |fl: &FacilityLocation| {
        let backend = CpuBackend::new(fl);
        MaximizerEngine::new(fl, GainRoute::Backend(&backend)).lazy_greedy(&cands, k)
    };
    let sol_dense = run(&dense);
    let rel_fixed = dense.eval(&run(&fixed).set) / sol_dense.value;
    let rel_adaptive = dense.eval(&run(&adaptive).set) / sol_dense.value;
    assert!(
        rel_adaptive >= 0.95,
        "adaptive floor broken: {rel_adaptive:.4} (fixed-t scored {rel_fixed:.4})"
    );
    assert!(
        rel_adaptive + 0.02 >= rel_fixed,
        "adaptive budget must never trail fixed t: {rel_adaptive:.4} vs {rel_fixed:.4}"
    );
}

#[test]
fn backend_construction_gauges_the_lsh_work_and_memory_accounts_for_the_index() {
    let n = 300;
    let d = 8;
    let data = clustered_rows(n, 6, d, 5);
    let exact =
        FacilityLocation::from_features_strat(&data, 0, Some(16), BuildStrategy::Exact, None);
    let lsh = FacilityLocation::from_features_strat(
        &data,
        0,
        Some(16),
        BuildStrategy::Lsh { tables: 4, bits: 3 },
        None,
    );
    // the hash tables are resident state: the ≥4× memory gate in the
    // bench must see them, so `resident_bytes` has to grow with the index
    assert!(
        lsh.resident_bytes() > exact.resident_bytes(),
        "resident_bytes must include the LSH tables ({} vs {})",
        lsh.resident_bytes(),
        exact.resident_bytes()
    );

    let (cands, bmax) = lsh.sparse_store().unwrap().lsh_stats().unwrap();
    let pool = Arc::new(ThreadPool::new(2, 16));
    let metrics = Arc::new(Metrics::new());
    let f: Arc<dyn BatchedDivergence> = Arc::new(lsh);
    let _backend =
        ShardedBackend::new(f, pool, Compute::Cpu, Arc::clone(&metrics)).unwrap();
    assert_eq!(metrics.counters.lsh_candidates.load(Ordering::Relaxed), cands);
    assert_eq!(metrics.counters.lsh_bucket_max.load(Ordering::Relaxed), bmax);
    assert!(cands > 0 && bmax > 0);

    // an exact-built objective gauges zero on both
    let metrics2 = Arc::new(Metrics::new());
    let f2: Arc<dyn BatchedDivergence> = Arc::new(exact);
    let _b2 = ShardedBackend::new(
        f2,
        Arc::new(ThreadPool::new(1, 16)),
        Compute::Cpu,
        Arc::clone(&metrics2),
    )
    .unwrap();
    assert_eq!(metrics2.counters.lsh_candidates.load(Ordering::Relaxed), 0);
    assert_eq!(metrics2.counters.lsh_bucket_max.load(Ordering::Relaxed), 0);
}
