//! Multi-process cluster leg: the coordinator drives real `ssctl
//! worker --stdio` child processes over their pipes, and the answer is
//! bit-identical to an in-process loopback cluster. This is the
//! closest the test suite gets to production topology — separate
//! address spaces, the protocol on real OS pipes, process exit as the
//! failure domain.

use std::process::{Child, Command, Stdio};
use std::thread;

use submodular_ss::algorithms::SsParams;
use submodular_ss::cluster::{
    ClusterConfig, ClusterCoordinator, WorkerConfig, WorkerRuntime,
};
use submodular_ss::data::{CorpusParams, NewsGenerator};
use submodular_ss::net::{loopback_pair, IoConn, Transport};
use submodular_ss::submodular::ObjectiveSpec;
use submodular_ss::util::vecmath::FeatureMatrix;

fn corpus(n: usize) -> (FeatureMatrix, usize) {
    let g = NewsGenerator::new(CorpusParams::default(), 5);
    let day = g.day(n, 0, 5);
    (day.feats, day.k.min(12))
}

fn cluster_cfg() -> ClusterConfig {
    ClusterConfig { shards: 6, seed: 11, ..Default::default() }
}

/// Spawn one worker child serving its stdio; its pipes become the
/// coordinator-side transport (we read its stdout, write its stdin).
fn spawn_worker_process(id: u64) -> (Child, Box<dyn Transport>) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_ssctl"))
        .args(["worker", "--id", &id.to_string(), "--workers", "2", "--threads", "2"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn ssctl worker");
    let stdin = child.stdin.take().expect("child stdin");
    let stdout = child.stdout.take().expect("child stdout");
    (child, Box::new(IoConn::new(stdout, stdin)))
}

#[test]
fn child_process_workers_match_the_in_process_answer() {
    let (rows, k) = corpus(400);
    let spec = ObjectiveSpec::FacilityLocation;
    let params = SsParams::default().with_seed(7);

    // In-process loopback reference (single worker).
    let reference = {
        let (coord_end, worker_end, _kill) = loopback_pair();
        let w = thread::spawn(move || {
            WorkerRuntime::new(WorkerConfig::default()).serve(Box::new(worker_end))
        });
        let coordinator =
            ClusterCoordinator::connect(vec![Box::new(coord_end)], cluster_cfg()).unwrap();
        let resp = coordinator.summarize(spec.clone(), &rows, k, &params).unwrap();
        drop(coordinator);
        assert!(w.join().unwrap().unwrap().saw_shutdown);
        resp
    };

    // Two real child processes, same logical shards.
    let (children, transports): (Vec<Child>, Vec<Box<dyn Transport>>) =
        (0..2u64).map(spawn_worker_process).unzip();
    let coordinator = ClusterCoordinator::connect(transports, cluster_cfg()).unwrap();
    let got = coordinator.summarize(spec, &rows, k, &params).unwrap();

    assert_eq!(got.summary, reference.summary, "summary differs across process boundary");
    assert_eq!(got.value.to_bits(), reference.value.to_bits(), "value not bit-identical");
    assert_eq!(got.union, reference.union, "survivor union differs");

    // Shutdown flows out over the pipes; each child must exit cleanly.
    drop(coordinator);
    for mut child in children {
        let status = child.wait().expect("wait on worker child");
        assert!(status.success(), "worker exited with {status:?}");
    }
}
