//! Sparse ↔ dense facility-location contract tests.
//!
//! The sparse top-t neighbor store is only allowed behind the kernel seams
//! because of three properties, each pinned here on **production paths**
//! (SS→greedy, the maximizer engine, streaming sessions) rather than on
//! store internals:
//!
//! 1. **Exactness at full t** — `t = n−1` stores every pairwise similarity,
//!    so every kernel, SS trajectory, greedy commit and stream snapshot is
//!    bit-identical to the dense matrix, across seeds and shard counts.
//! 2. **History-freedom where promised** — incremental row-border appends
//!    reproduce fresh construction exactly (any t), and retain does too in
//!    the no-eviction-loss regime (`t ≥ n_final − 1`).
//! 3. **Utility floor at truncated t** — with `t = O(log n)` neighbors on
//!    clustered data, greedy under the truncated objective keeps ≥ 0.95 of
//!    the dense-objective value, at a fraction of the memory.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use submodular_ss::algorithms::{
    ss_then_greedy, CpuBackend, GainRoute, MaximizerEngine, SsParams,
};
use submodular_ss::coordinator::{Compute, Metrics, ShardedBackend};
use submodular_ss::stream::{ObjectiveSpec, SnapshotMode, StreamConfig, StreamSession};
use submodular_ss::submodular::{
    BatchedDivergence, BuildStrategy, FacilityLocation, SubmodularFn, DENSE_CROSSOVER,
};
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

/// Byte-tracking allocator: `PEAK` records the high-water mark of live
/// heap bytes, which is what the O(n·t) peak-residency assertion below
/// measures (the event-counting allocator in `alloc_steady_state.rs`
/// can't see sizes).
struct PeakAlloc;

unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        let live = LIVE.fetch_add(l.size(), Ordering::Relaxed) + l.size();
        PEAK.fetch_max(live, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        LIVE.fetch_sub(l.size(), Ordering::Relaxed);
        System.dealloc(p, l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        let live = LIVE.fetch_add(l.size(), Ordering::Relaxed) + l.size();
        PEAK.fetch_max(live, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        if n > l.size() {
            let grow = n - l.size();
            let live = LIVE.fetch_add(grow, Ordering::Relaxed) + grow;
            PEAK.fetch_max(live, Ordering::Relaxed);
        } else {
            LIVE.fetch_sub(l.size() - n, Ordering::Relaxed);
        }
        System.realloc(p, l, n)
    }
}

#[global_allocator]
static GLOBAL: PeakAlloc = PeakAlloc;

/// Signed rows: about half the pairwise cosines clamp to zero, so the
/// sparse store sees genuinely absent entries, not just truncated ones.
fn rows(n: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = rng.f32() - 0.3;
        }
    }
    m
}

/// `clusters` tight groups: each row is its cluster center plus small
/// noise, so a row's informative neighbors are its ~n/clusters cluster
/// mates — the regime where top-t truncation is nearly lossless.
fn clustered_rows(n: usize, clusters: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut centers = FeatureMatrix::zeros(clusters, d);
    for c in 0..clusters {
        for j in 0..d {
            centers.row_mut(c)[j] = rng.f32() * 2.0 - 1.0;
        }
    }
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        let c = i % clusters;
        for j in 0..d {
            m.row_mut(i)[j] = centers.row(c)[j] + 0.05 * (rng.f32() - 0.5);
        }
    }
    m
}

#[test]
fn full_t_sparse_matches_dense_through_ss_and_the_engine() {
    let d = 9;
    let n = 150;
    let k = 7;
    for seed in [3u64, 17] {
        let data = rows(n, d, seed);
        let dense = FacilityLocation::from_features_dense(&data);
        let sparse = FacilityLocation::from_features_sparse(&data, n - 1);
        assert!(!dense.is_sparse());
        assert!(sparse.is_sparse());

        // --- serial backend: the paper pipeline end to end ---
        let params = SsParams::default().with_seed(seed);
        let bd = CpuBackend::new(&dense);
        let bs = CpuBackend::new(&sparse);
        let (ss_d, sol_d) = ss_then_greedy(&dense, &bd, k, &params);
        let (ss_s, sol_s) = ss_then_greedy(&sparse, &bs, k, &params);
        assert_eq!(ss_d.kept, ss_s.kept, "seed {seed}: SS trajectories diverged");
        assert_eq!(sol_d.set, sol_s.set, "seed {seed}: greedy commits diverged");
        assert_eq!(sol_d.value.to_bits(), sol_s.value.to_bits());

        // --- sharded backends at several widths ---
        for threads in [1usize, 3] {
            let pool = Arc::new(ThreadPool::new(threads, 16));
            let run = |fl: &FacilityLocation| {
                let f: Arc<dyn BatchedDivergence> = Arc::new(fl.clone());
                let backend = ShardedBackend::new(
                    f,
                    Arc::clone(&pool),
                    Compute::Cpu,
                    Arc::new(Metrics::new()),
                )
                .unwrap();
                ss_then_greedy(fl, &backend, k, &params)
            };
            let (sd, gd) = run(&dense);
            let (ssp, gs) = run(&sparse);
            assert_eq!(sd.kept, ssp.kept, "seed {seed}/threads {threads}");
            assert_eq!(gd.set, gs.set);
            assert_eq!(gd.value.to_bits(), gs.value.to_bits());
        }

        // --- engine modes over the full candidate list ---
        let cands: Vec<usize> = (0..n).collect();
        let run_engine = |fl: &FacilityLocation| {
            let backend = CpuBackend::new(fl);
            let mut eng = MaximizerEngine::new(fl, GainRoute::Backend(&backend));
            let lazy = eng.lazy_greedy(&cands, k);
            let stoch = eng.stochastic_greedy(&cands, k, 0.1, seed);
            (lazy, stoch)
        };
        let (ld, sd) = run_engine(&dense);
        let (ls, ss) = run_engine(&sparse);
        assert_eq!(ld.set, ls.set);
        assert_eq!(ld.value.to_bits(), ls.value.to_bits());
        assert_eq!(sd.set, ss.set);
        assert_eq!(sd.value.to_bits(), ss.value.to_bits());
    }
}

#[test]
fn full_t_sparse_stream_matches_the_dense_stream_across_windows() {
    // windowed sessions exercise the full mutation surface: lazy build,
    // row-border appends, retain compaction, park/resume of the backend.
    // At t = n−1 the store never truncates, so every window of the sparse
    // session must reproduce the dense session bit for bit.
    let d = 8;
    let n = 240;
    let data = rows(n, d, 23);
    let run = |spec: ObjectiveSpec| {
        let mut s = StreamSession::new(
            spec,
            d,
            StreamConfig::new(6)
                .with_ss(SsParams::default().with_seed(11))
                .with_high_water(70),
            Arc::new(ThreadPool::new(2, 16)),
            Arc::new(Metrics::new()),
        )
        .unwrap();
        let mut windows = 0;
        for chunk in data.data().chunks(d * 55) {
            windows += s.append(chunk).unwrap().resparsifies;
        }
        let snap = s.snapshot_summary(SnapshotMode::Final).unwrap();
        (snap, windows)
    };
    let (snap_dense, w_dense) = run(ObjectiveSpec::FacilityLocation);
    let (snap_sparse, w_sparse) = run(ObjectiveSpec::FacilityLocationSparse {
        t: (n - 1) as u32,
        crossover: 0,
        build: BuildStrategy::Auto,
    });
    assert!(w_dense >= 2, "session must have windowed, got {w_dense}");
    assert_eq!(w_dense, w_sparse, "window schedules diverged");
    assert_eq!(snap_dense.summary, snap_sparse.summary);
    assert_eq!(snap_dense.value.to_bits(), snap_sparse.value.to_bits());
    assert_eq!(snap_dense.live, snap_sparse.live);
    assert_eq!(snap_dense.ss_rounds, snap_sparse.ss_rounds);
}

#[test]
fn append_then_retain_roundtrips_to_fresh_construction() {
    let d = 7;
    let n = 60;
    let full = rows(n, d, 5);
    let probes: [&[usize]; 4] = [&[0], &[3, 41, 59], &[7, 8, 9, 30, 31], &[0, 20, 40, 58]];

    // appends at truncated t: the unique selection order makes the grown
    // store equal the fresh batch build exactly
    let start = 35;
    let mut grown =
        FacilityLocation::from_features_sparse(&full.gather(&(0..start).collect::<Vec<_>>()), 12);
    for j in start..n {
        let prefix = full.gather(&(0..=j).collect::<Vec<_>>());
        grown.append_row_from_features(&prefix).expect("sparse appends report update counts");
    }
    let fresh = FacilityLocation::from_features_sparse(&full, 12);
    for p in probes {
        assert_eq!(grown.eval(p).to_bits(), fresh.eval(p).to_bits());
    }
    let (gs, fs) = (grown.singleton_complements(), fresh.singleton_complements());
    for (a, b) in gs.iter().zip(&fs) {
        assert_eq!(a.to_bits(), b.to_bits(), "singleton complements diverged after appends");
    }

    // retain in the no-loss regime (t ≥ n_final − 1): compaction equals a
    // fresh build over the surviving rows
    let keep: Vec<usize> = (0..n).filter(|i| i % 2 == 0).collect();
    let mut retained = FacilityLocation::from_features_sparse(&full, n - 1);
    assert!(retained.supports_retain());
    assert!(retained.retain_elements(&keep));
    let rebuilt = FacilityLocation::from_features_sparse(&full.gather(&keep), n - 1);
    assert_eq!(retained.n(), keep.len());
    let small: [&[usize]; 3] = [&[0], &[1, 10, 29], &[2, 3, 4, 25]];
    for p in small {
        assert_eq!(retained.eval(p).to_bits(), rebuilt.eval(p).to_bits());
    }
    let (rs, bs) = (retained.singleton_complements(), rebuilt.singleton_complements());
    for (a, b) in rs.iter().zip(&bs) {
        assert_eq!(a.to_bits(), b.to_bits(), "singleton complements diverged after retain");
    }
}

#[test]
fn truncated_t_keeps_the_utility_floor_on_clustered_data() {
    let n = 360;
    let d = 12;
    let k = 9;
    let data = clustered_rows(n, k, d, 7);
    let t = FacilityLocation::auto_neighbors(n);
    assert!(t < n / 4, "the budget must be a genuine truncation (t = {t})");
    let dense = FacilityLocation::from_features_dense(&data);
    let sparse = FacilityLocation::from_features_sparse(&data, t);

    let cands: Vec<usize> = (0..n).collect();
    let run = |fl: &FacilityLocation| {
        let backend = CpuBackend::new(fl);
        MaximizerEngine::new(fl, GainRoute::Backend(&backend)).lazy_greedy(&cands, k)
    };
    let sol_dense = run(&dense);
    let sol_sparse = run(&sparse);

    // the truncated objective lower-bounds the dense one on every set
    assert!(sol_sparse.value <= dense.eval(&sol_sparse.set) + 1e-9);
    // and its greedy solution, scored by the DENSE objective, keeps the floor
    let achieved = dense.eval(&sol_sparse.set);
    assert!(
        achieved >= 0.95 * sol_dense.value,
        "utility floor broken: sparse-greedy set scores {achieved:.4} vs dense {:.4}",
        sol_dense.value
    );
    // at a real memory discount
    assert!(sparse.resident_bytes() * 2 < dense.resident_bytes());
}

#[test]
fn above_the_crossover_memory_stays_linear_in_t() {
    // the acceptance shape: a ground set the dense matrix would take
    // n²·4 B = 100 MB for, held in O(n·t) and still serving the engine
    let n = 5000;
    let d = 6;
    let data = rows(n, d, 31);
    let pool = ThreadPool::new(4, 16);
    // delta-based peak measurement around the build: whatever the other
    // tests in this binary hold live is in `before`, and their concurrent
    // churn is far below the 25 MB headroom asserted here
    let before = LIVE.load(Ordering::Relaxed);
    PEAK.store(before, Ordering::Relaxed);
    let fl = FacilityLocation::from_features_with(&data, DENSE_CROSSOVER, None, Some((&pool, 8)));
    let peak_during_build = PEAK.load(Ordering::Relaxed).saturating_sub(before);
    assert!(fl.is_sparse(), "n = {n} ≥ crossover must auto-select the sparse store");
    assert_eq!(fl.sparse_rows(), n);
    let dense_bytes = n * n * std::mem::size_of::<f32>();
    assert!(
        peak_during_build < dense_bytes / 4,
        "building the sparse store allocated a peak of {peak_during_build} B — \
         the n² matrix ({dense_bytes} B) must never be materialized, even transiently"
    );
    assert!(
        fl.resident_bytes() * 4 < dense_bytes,
        "resident {} B misses the 4× reduction vs dense {} B",
        fl.resident_bytes(),
        dense_bytes
    );
    // the store serves real maximization at this scale: a bounded
    // candidate slate keeps the debug-build test fast
    let cands: Vec<usize> = (0..400).collect();
    let backend = CpuBackend::new(&fl);
    let sol = MaximizerEngine::new(&fl, GainRoute::Backend(&backend)).lazy_greedy(&cands, 5);
    assert_eq!(sol.set.len(), 5);
    assert!(sol.value > 0.0);
}
