//! Wire-protocol property tests: every message round-trips through the
//! framed codec bit-exactly, and every corruption mode — truncation,
//! bit flips, reordering, garbage — decodes to a **typed** error with no
//! panic and no partially-applied message. This is the protocol's
//! safety contract (ISSUE 10 acceptance): a hostile or broken peer can
//! end a connection, never a process.

use submodular_ss::algorithms::{Sampling, SsParams};
use submodular_ss::coordinator::ServiceError;
use submodular_ss::net::{encode_frame, tag, FrameDecoder, Message, WireError, PROTO_VERSION};
use submodular_ss::submodular::{BuildStrategy, Concave, ObjectiveSpec};
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

fn rows(n: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = rng.f32();
        }
    }
    m
}

/// One instance of every message kind (and every enum arm that changes
/// the encoding), the corpus all the property tests run over.
fn corpus() -> Vec<Message> {
    let params = SsParams {
        r: 8,
        c: 8.0,
        seed: 0xDEAD_BEEF,
        sampling: Sampling::Importance,
        min_keep: 12,
    };
    vec![
        Message::Hello { version: PROTO_VERSION, peer_id: 3 },
        Message::HelloAck { version: PROTO_VERSION, peer_id: 9 },
        Message::SummarizeReq {
            job: 42,
            spec: ObjectiveSpec::Features(Concave::Pow(250)),
            rows: rows(7, 5, 1),
            k: 3,
            params: params.clone(),
        },
        Message::SummarizeResp {
            job: 42,
            summary: vec![5, 0, 3],
            value: 12.625,
            n: 7,
            reduced: 5,
            ss_rounds: 2,
        },
        Message::ShardAssign {
            job: 7,
            shard: 2,
            spec: ObjectiveSpec::FacilityLocationSparse {
                t: 16,
                crossover: 2048,
                build: BuildStrategy::Lsh { tables: 4, bits: 10 },
            },
            params,
            ids: vec![3, 17, 900, 4096],
            rows: rows(4, 3, 2),
        },
        Message::ShardCore { job: 7, shard: 2, kept: vec![17, 4096], rounds: 4 },
        Message::HealthProbe { nonce: 0xFFFF_FFFF_FFFF },
        Message::HealthSnap {
            nonce: 0xFFFF_FFFF_FFFF,
            jobs_done: 12,
            busy: 2,
            metrics_json: "{\"scope\":\"worker-0\"}".into(),
        },
        Message::ErrorMsg { job: 9, err: ServiceError::QueueFull(()) },
        Message::ErrorMsg { job: 9, err: ServiceError::ServiceDown },
        Message::ErrorMsg { job: 9, err: ServiceError::UnknownStream(77) },
        Message::ErrorMsg {
            job: 9,
            err: ServiceError::Rejected { reason: "stream quarantined: unit test".into() },
        },
        Message::ErrorMsg { job: 9, err: ServiceError::Cancelled },
        Message::ErrorMsg { job: 9, err: ServiceError::DeadlineExceeded },
        Message::Cancel { job: 1 },
        Message::Shutdown,
        // spec arms not hit above
        Message::ShardAssign {
            job: 8,
            shard: 0,
            spec: ObjectiveSpec::FacilityLocation,
            params: SsParams::default(),
            ids: vec![0],
            rows: rows(1, 2, 3),
        },
        Message::ShardAssign {
            job: 9,
            shard: 1,
            spec: ObjectiveSpec::FacilityLocationSparse {
                t: 8,
                crossover: 512,
                build: BuildStrategy::Auto,
            },
            params: SsParams::default(),
            ids: vec![1, 2],
            rows: rows(2, 2, 4),
        },
        Message::SummarizeReq {
            job: 10,
            spec: ObjectiveSpec::Features(Concave::Log1p),
            rows: rows(2, 2, 5),
            k: 1,
            params: SsParams::default(),
        },
    ]
}

fn errors_eq(a: &ServiceError, b: &ServiceError) -> bool {
    a.to_string() == b.to_string()
}

fn messages_eq(a: &Message, b: &Message) -> bool {
    match (a, b) {
        (Message::ErrorMsg { job: ja, err: ea }, Message::ErrorMsg { job: jb, err: eb }) => {
            ja == jb && errors_eq(ea, eb)
        }
        _ => a == b,
    }
}

#[test]
fn every_message_roundtrips_bit_exactly() {
    for msg in corpus() {
        let wire = encode_frame(msg.tag(), 0, &msg.encode());
        let mut dec = FrameDecoder::new();
        dec.push(&wire);
        let frame = dec.next_frame().unwrap().expect("complete frame");
        let back = Message::decode(frame.tag, &frame.payload).unwrap();
        assert!(messages_eq(&msg, &back), "round-trip mismatch for tag {}", msg.tag());
        assert_eq!(back.encode(), msg.encode(), "re-encode must be byte-identical");
    }
}

#[test]
fn a_whole_conversation_reassembles_from_one_byte_chunks() {
    let msgs = corpus();
    let mut stream = Vec::new();
    for (seq, msg) in msgs.iter().enumerate() {
        stream.extend_from_slice(&encode_frame(msg.tag(), seq as u64, &msg.encode()));
    }
    let mut dec = FrameDecoder::new();
    let mut got = Vec::new();
    for &b in &stream {
        dec.push(std::slice::from_ref(&b));
        while let Some(f) = dec.next_frame().unwrap() {
            got.push(Message::decode(f.tag, &f.payload).unwrap());
        }
    }
    dec.finish().unwrap();
    assert_eq!(got.len(), msgs.len());
    for (a, b) in msgs.iter().zip(&got) {
        assert!(messages_eq(a, b));
    }
}

#[test]
fn every_truncation_is_incomplete_or_typed_never_panics() {
    for msg in corpus() {
        let wire = encode_frame(msg.tag(), 0, &msg.encode());
        for cut in 0..wire.len() {
            let mut dec = FrameDecoder::new();
            dec.push(&wire[..cut]);
            match dec.next_frame() {
                Ok(None) => {
                    // incomplete — and EOF here is a typed truncation
                    if cut > 0 {
                        assert!(matches!(dec.finish(), Err(WireError::Corrupt(_))));
                    }
                }
                Ok(Some(_)) => panic!("a strict prefix cannot be a complete frame"),
                Err(WireError::Corrupt(_)) => {} // typed is fine too
                Err(other) => panic!("unexpected error class {other:?}"),
            }
        }
    }
}

#[test]
fn every_single_bit_flip_is_rejected_typed() {
    for msg in corpus() {
        let wire = encode_frame(msg.tag(), 0, &msg.encode());
        // flip one bit per byte position (bit index varies by position so
        // the sweep covers all 8 lanes across the frame)
        for pos in 0..wire.len() {
            let mut bad = wire.clone();
            bad[pos] ^= 1 << (pos % 8);
            let mut dec = FrameDecoder::new();
            dec.push(&bad);
            match dec.next_frame() {
                // flips in the length prefix can make the frame "longer"
                // → incomplete, never delivered
                Ok(None) => {}
                Ok(Some(f)) => {
                    // the only acceptable delivery would be... none: the
                    // checksum covers tag, seq and payload, and a length
                    // flip moves the checksum window. Message-layer decode
                    // must therefore never see flipped bytes as valid.
                    panic!(
                        "bit flip at {pos} (tag {}) slipped through as frame tag {}",
                        msg.tag(),
                        f.tag
                    );
                }
                Err(WireError::Corrupt(_)) | Err(WireError::Reorder { .. }) => {}
                Err(other) => panic!("unexpected error class {other:?}"),
            }
        }
    }
}

#[test]
fn reordered_and_replayed_frames_are_typed_and_poison() {
    let a = encode_frame(tag::CANCEL, 0, &Message::Cancel { job: 1 }.encode());
    let b = encode_frame(tag::CANCEL, 1, &Message::Cancel { job: 2 }.encode());

    // reorder: seq 1 before seq 0
    let mut dec = FrameDecoder::new();
    dec.push(&b);
    dec.push(&a);
    assert!(matches!(dec.next_frame(), Err(WireError::Reorder { expected: 0, got: 1 })));
    assert!(dec.next_frame().is_err(), "decoder stays poisoned");

    // replay: seq 0 twice
    let mut dec = FrameDecoder::new();
    dec.push(&a);
    dec.push(&a);
    assert!(dec.next_frame().unwrap().is_some());
    assert!(matches!(dec.next_frame(), Err(WireError::Reorder { expected: 1, got: 0 })));
}

#[test]
fn garbage_payloads_decode_to_typed_errors_for_every_tag() {
    let mut rng = Rng::new(99);
    let tags = [
        tag::HELLO,
        tag::HELLO_ACK,
        tag::SUMMARIZE_REQ,
        tag::SUMMARIZE_RESP,
        tag::SHARD_ASSIGN,
        tag::SHARD_CORE,
        tag::HEALTH_PROBE,
        tag::HEALTH_SNAP,
        tag::ERROR,
        tag::CANCEL,
        tag::SHUTDOWN,
        0,    // unknown
        0xEE, // unknown
    ];
    for t in tags {
        for len in [0usize, 1, 3, 8, 17, 64] {
            let payload: Vec<u8> = (0..len).map(|_| (rng.below(256)) as u8).collect();
            match Message::decode(t, &payload) {
                Ok(m) => {
                    // only structurally complete payloads may decode; a
                    // re-encode must reproduce the exact bytes (no
                    // partial/ambiguous parse)
                    assert_eq!(m.encode(), payload, "tag {t} len {len} lossy decode");
                }
                Err(WireError::Corrupt(_)) => {}
                Err(other) => panic!("tag {t}: unexpected error class {other:?}"),
            }
        }
    }
}

#[test]
fn decode_applies_no_partial_state_on_failure() {
    // a ShardAssign whose ids parse but whose rows are short must fail as
    // a unit — nothing half-decoded escapes Message::decode by design
    // (it returns Result<Message, _>), so the check here is that the
    // failure is typed and the same bytes fail identically twice
    let msg = Message::ShardAssign {
        job: 1,
        shard: 0,
        spec: ObjectiveSpec::Features(Concave::Sqrt),
        params: SsParams::default(),
        ids: vec![1, 2, 3],
        rows: rows(3, 4, 6),
    };
    let mut payload = msg.encode();
    payload.truncate(payload.len() - 5); // tear the row data
    let e1 = Message::decode(msg.tag(), &payload).unwrap_err();
    let e2 = Message::decode(msg.tag(), &payload).unwrap_err();
    assert!(matches!(e1, WireError::Corrupt(_)));
    assert_eq!(format!("{e1}"), format!("{e2}"), "decode is pure");
}
