//! End-to-end cluster acceptance (ISSUE 10): the coordinator fanning SS
//! out over N loopback workers returns summaries **bit-identical** across
//! worker counts under fixed seeds, survives worker death mid-run via
//! reshard + bounded retry, and every wire decode failure surfaces as a
//! typed [`ServiceError`] — never a panic.
//!
//! The invariance hinges on logical shards: `ClusterConfig::shards` fixes
//! the partition (seeded permutation) and the per-shard SS seeds, and the
//! survivor union is order-normalized, so *which worker* ran a shard —
//! first try or after a reshard — cannot show up in the result.

use std::sync::atomic::Ordering;
use std::thread::JoinHandle;
use std::time::Duration;

use submodular_ss::algorithms::SsParams;
use submodular_ss::cluster::{
    ClusterConfig, ClusterCoordinator, ClusterResponse, WorkerConfig, WorkerRuntime,
};
use submodular_ss::coordinator::{JobOptions, ServiceError};
use submodular_ss::data::{CorpusParams, NewsGenerator};
use submodular_ss::net::{
    encode_frame, loopback_pair, tag, FrameDecoder, KillSwitch, Message, Transport, WireError,
    WireRead, WireWrite, PROTO_VERSION,
};
use submodular_ss::submodular::{BuildStrategy, Concave, ObjectiveSpec};
use submodular_ss::util::vecmath::FeatureMatrix;

fn corpus(n: usize) -> (FeatureMatrix, usize) {
    let generator = NewsGenerator::new(CorpusParams::default(), 5);
    let day = generator.day(n, 0, 5);
    (day.feats, day.k.min(12))
}

struct Cluster {
    coordinator: ClusterCoordinator,
    threads: Vec<JoinHandle<Result<submodular_ss::cluster::WorkerReport, WireError>>>,
    kills: Vec<KillSwitch>,
}

fn spawn_cluster(workers: usize, cfg: ClusterConfig) -> Cluster {
    let mut transports: Vec<Box<dyn Transport>> = Vec::new();
    let mut threads = Vec::new();
    let mut kills = Vec::new();
    for w in 0..workers {
        let (coord_end, worker_end, kill) = loopback_pair();
        transports.push(Box::new(coord_end));
        kills.push(kill);
        threads.push(std::thread::spawn(move || {
            WorkerRuntime::new(WorkerConfig { worker_id: w as u64, ..WorkerConfig::default() })
                .serve(Box::new(worker_end))
        }));
    }
    let coordinator = ClusterCoordinator::connect(transports, cfg).expect("handshake");
    Cluster { coordinator, threads, kills }
}

impl Cluster {
    /// Shut down and join; killed workers are allowed to report a wire
    /// error, survivors must have seen the explicit `Shutdown`.
    fn finish(self, killed: &[usize]) {
        drop(self.coordinator);
        for (i, h) in self.threads.into_iter().enumerate() {
            let out = h.join().expect("worker thread");
            if killed.contains(&i) {
                assert!(out.is_err(), "killed worker {i} should report a transport error");
            } else {
                let report = out.expect("surviving worker serve");
                assert!(report.saw_shutdown, "surviving worker {i} ends via explicit shutdown");
            }
        }
    }
}

fn run(
    workers: usize,
    cfg: ClusterConfig,
    spec: ObjectiveSpec,
    rows: &FeatureMatrix,
    k: usize,
    params: &SsParams,
) -> ClusterResponse {
    let cluster = spawn_cluster(workers, cfg);
    let resp = cluster.coordinator.summarize(spec, rows, k, params).expect("cluster summarize");
    cluster.finish(&[]);
    resp
}

#[test]
fn summaries_are_bit_identical_across_worker_counts() {
    let (rows, k) = corpus(500);
    let params = SsParams::default().with_seed(7);
    let specs = [
        ObjectiveSpec::Features(Concave::Sqrt),
        ObjectiveSpec::FacilityLocation,
        ObjectiveSpec::FacilityLocationSparse {
            t: 8,
            crossover: 64,
            build: BuildStrategy::Auto,
        },
    ];
    for spec in specs {
        for shards in [1u32, 5, 8] {
            let cfg = ClusterConfig { shards, seed: 11, ..ClusterConfig::default() };
            let reference = run(1, cfg.clone(), spec, &rows, k, &params);
            for workers in [2usize, 4] {
                let got = run(workers, cfg.clone(), spec, &rows, k, &params);
                assert_eq!(
                    got.summary, reference.summary,
                    "{spec:?} shards={shards} workers={workers}: summary diverged"
                );
                assert_eq!(
                    got.value.to_bits(),
                    reference.value.to_bits(),
                    "{spec:?} shards={shards} workers={workers}: value diverged"
                );
                assert_eq!(got.union, reference.union, "survivor union diverged");
                assert_eq!(got.shard_rounds, reference.shard_rounds, "shard rounds diverged");
            }
        }
    }
}

/// A worker that handshakes honestly, accepts its first `ShardAssign`,
/// then dies without answering — the deterministic stand-in for a worker
/// process crashing with work in flight.
fn accept_one_then_die(end: submodular_ss::net::LoopbackEnd) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let (mut r, mut w) = (Box::new(end) as Box<dyn Transport>).split();
        let mut dec = FrameDecoder::new();
        let mut buf = [0u8; 1 << 16];
        let mut next = || loop {
            if let Some(f) = dec.next_frame().unwrap() {
                break f;
            }
            let got = r.read_some(&mut buf).unwrap();
            assert!(got > 0, "peer hung up early");
            dec.push(&buf[..got]);
        };
        let hello = next();
        assert_eq!(hello.tag, tag::HELLO);
        let ack = Message::HelloAck { version: PROTO_VERSION, peer_id: 99 };
        w.write_all_bytes(&encode_frame(tag::HELLO_ACK, 0, &ack.encode())).unwrap();
        w.flush_bytes().unwrap();
        loop {
            let f = next();
            if f.tag == tag::SHARD_ASSIGN {
                Message::decode(f.tag, &f.payload).expect("assignment decodes");
                return; // drop both halves: connection closes, core never comes
            }
        }
    })
}

#[test]
fn worker_death_reshards_onto_survivors_without_changing_the_answer() {
    let (rows, k) = corpus(400);
    let params = SsParams::default().with_seed(3);
    let spec = ObjectiveSpec::Features(Concave::Sqrt);
    let cfg = ClusterConfig { shards: 6, seed: 2, max_retries: 4, ..ClusterConfig::default() };

    let reference = run(1, cfg.clone(), spec, &rows, k, &params);

    // worker 0 is real; worker 1 takes a shard to its grave. Round-robin
    // dispatch guarantees it receives one, so the reshard path always runs.
    let (coord0, worker0, _k0) = loopback_pair();
    let (coord1, worker1, _k1) = loopback_pair();
    let real = std::thread::spawn(move || {
        WorkerRuntime::new(WorkerConfig { worker_id: 0, ..WorkerConfig::default() })
            .serve(Box::new(worker0))
    });
    let doomed = accept_one_then_die(worker1);
    let coordinator = ClusterCoordinator::connect(
        vec![Box::new(coord0), Box::new(coord1)],
        cfg,
    )
    .expect("handshake");

    let got = coordinator.summarize(spec, &rows, k, &params).expect("summarize survives");
    assert_eq!(got.summary, reference.summary, "reshard changed the summary");
    assert_eq!(got.value.to_bits(), reference.value.to_bits(), "reshard changed the value");
    assert!(got.retries >= 1, "the doomed worker's shard must have been retried");

    let c = &coordinator.metrics().counters;
    assert!(c.shard_retries.load(Ordering::Relaxed) >= 1, "retry must be metered");
    assert!(c.shards_dispatched.load(Ordering::Relaxed) >= 7, "6 shards + >=1 re-dispatch");
    let deaths: u64 = std::iter::once(c.worker_deaths.load(Ordering::Relaxed))
        .chain(
            coordinator
                .worker_scopes()
                .iter()
                .map(|s| s.counters.worker_deaths.load(Ordering::Relaxed)),
        )
        .sum();
    assert_eq!(deaths, 1, "one death, counted exactly once across scopes");
    assert_eq!(coordinator.live_workers(), 1);

    drop(coordinator);
    doomed.join().unwrap();
    let report = real.join().unwrap().expect("surviving worker serve");
    assert!(report.saw_shutdown);
}

#[test]
fn mid_run_worker_kill_recovers_deterministically() {
    let (rows, k) = corpus(600);
    let params = SsParams::default().with_seed(13);
    let spec = ObjectiveSpec::Features(Concave::Sqrt);
    let cfg = ClusterConfig {
        shards: 8,
        seed: 4,
        max_retries: 6,
        shard_timeout: Some(Duration::from_secs(2)),
        ..ClusterConfig::default()
    };

    let reference = run(1, cfg.clone(), spec, &rows, k, &params);

    let cluster = spawn_cluster(4, cfg);
    let kill = cluster.kills[0].clone();
    let killer = std::thread::spawn(move || {
        // land somewhere inside the fan-out (or harmlessly after it)
        std::thread::sleep(Duration::from_millis(15));
        kill.kill();
    });
    let got = cluster.coordinator.summarize(spec, &rows, k, &params).expect("summarize survives");
    killer.join().unwrap();
    assert_eq!(got.summary, reference.summary, "mid-run kill changed the summary");
    assert_eq!(got.value.to_bits(), reference.value.to_bits(), "mid-run kill changed the value");
    cluster.finish(&[0]);
}

#[test]
fn corrupt_worker_stream_is_a_typed_error_never_a_panic() {
    // an "evil worker": completes the handshake honestly, then spews
    // garbage. The coordinator must declare the connection dead with a
    // typed decode error and fail the request with a typed ServiceError.
    let (coord_end, worker_end, _kill) = loopback_pair();
    let evil = std::thread::spawn(move || {
        let (mut r, mut w) = (Box::new(worker_end) as Box<dyn Transport>).split();
        let mut dec = FrameDecoder::new();
        let mut buf = [0u8; 4096];
        let hello = loop {
            if let Some(f) = dec.next_frame().unwrap() {
                break f;
            }
            let got = r.read_some(&mut buf).unwrap();
            assert!(got > 0, "peer hung up mid-handshake");
            dec.push(&buf[..got]);
        };
        let msg = Message::decode(hello.tag, &hello.payload).unwrap();
        assert!(matches!(msg, Message::Hello { .. }));
        let ack = Message::HelloAck { version: PROTO_VERSION, peer_id: 666 };
        w.write_all_bytes(&encode_frame(tag::HELLO_ACK, 0, &ack.encode())).unwrap();
        w.write_all_bytes(&[0xAB; 64]).unwrap(); // not a frame
        w.flush_bytes().unwrap();
        // keep the connection open so the coordinator's verdict comes
        // from the corrupt bytes, not an EOF
        std::thread::sleep(Duration::from_millis(300));
    });

    let (rows, k) = corpus(200);
    let cfg = ClusterConfig { shards: 2, seed: 1, max_retries: 1, ..ClusterConfig::default() };
    let coordinator = ClusterCoordinator::connect(vec![Box::new(coord_end)], cfg)
        .expect("handshake itself is clean");
    let err = coordinator
        .summarize(
            ObjectiveSpec::Features(Concave::Sqrt),
            &rows,
            k,
            &SsParams::default(),
        )
        .expect_err("a corrupt-only cluster cannot serve");
    assert!(
        matches!(err, ServiceError::Rejected { .. } | ServiceError::ServiceDown),
        "unexpected error class: {err:?}"
    );
    assert!(
        coordinator.worker_scopes()[0]
            .counters
            .wire_decode_errors
            .load(Ordering::Relaxed)
            >= 1,
        "the decode failure must be metered on the connection's scope"
    );
    assert_eq!(coordinator.live_workers(), 0);
    drop(coordinator);
    evil.join().unwrap();
}

#[test]
fn expired_deadline_propagates_as_deadline_exceeded() {
    let (rows, k) = corpus(200);
    let cluster = spawn_cluster(2, ClusterConfig { shards: 4, ..ClusterConfig::default() });
    let err = cluster
        .coordinator
        .summarize_with(
            ObjectiveSpec::Features(Concave::Sqrt),
            &rows,
            k,
            &SsParams::default(),
            JobOptions::default().with_timeout(Duration::ZERO),
        )
        .expect_err("an already-expired deadline cannot succeed");
    assert!(matches!(err, ServiceError::DeadlineExceeded), "got {err:?}");
    // the cluster is still healthy for the next request
    let ok = cluster
        .coordinator
        .summarize(ObjectiveSpec::Features(Concave::Sqrt), &rows, k, &SsParams::default())
        .expect("cluster still serves after a shed request");
    assert!(!ok.summary.is_empty());
    cluster.finish(&[]);
}

#[test]
fn health_probes_report_per_worker_progress() {
    let (rows, k) = corpus(200);
    let cluster = spawn_cluster(2, ClusterConfig { shards: 4, ..ClusterConfig::default() });
    let before = cluster.coordinator.health(Duration::from_secs(5));
    assert_eq!(before.len(), 2);
    for h in before.iter() {
        let h = h.as_ref().expect("live worker answers probes");
        assert_eq!(h.jobs_done, 0);
        assert_eq!(h.busy, 0);
    }
    cluster
        .coordinator
        .summarize(ObjectiveSpec::Features(Concave::Sqrt), &rows, k, &SsParams::default())
        .expect("summarize");
    let after = cluster.coordinator.health(Duration::from_secs(5));
    let done: u64 = after.iter().flatten().map(|h| h.jobs_done).sum();
    assert!(done >= 4, "4 logical shards completed somewhere, saw {done}");
    for h in after.iter().flatten() {
        assert!(h.metrics_json.contains("\"scope\""), "snapshot carries the metrics scope");
    }
    cluster.finish(&[]);
}
