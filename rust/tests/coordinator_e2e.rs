//! Integration: the parallel coordinator must reproduce the
//! single-threaded SS reference exactly — for every objective kind, not
//! just the paper's feature-based function — and the service must survive
//! concurrent load with correct routing.

use std::sync::Arc;

use submodular_ss::algorithms::{
    lazy_greedy, sparsify, sparsify_candidates, sparsify_candidates_reference, CpuBackend,
    Sampling, SsParams,
};
use submodular_ss::coordinator::{
    Compute, Metrics, Objective, ServiceConfig, ShardedBackend, SummarizationService,
    SummarizeRequest,
};
use submodular_ss::data::{CorpusParams, NewsGenerator};
use submodular_ss::submodular::{BatchedDivergence, FacilityLocation, FeatureBased, Mixture};
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

fn day_feats(n: usize, seed: u64) -> (FeatureBased, usize) {
    let g = NewsGenerator::new(
        CorpusParams { vocab_size: 800, d: 64, ..Default::default() },
        seed,
    );
    let day = g.day(n, 0, seed);
    (FeatureBased::sqrt(day.feats.clone()), day.k)
}

fn random_feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
        }
    }
    m
}

/// The three production objective kinds over the same feature substrate.
fn objective_instance(kind: &str, n: usize, seed: u64) -> Arc<dyn BatchedDivergence> {
    let feats = random_feats(n, 24, seed);
    match kind {
        "features" => Arc::new(FeatureBased::sqrt(feats)),
        "facility" => Arc::new(FacilityLocation::from_features(&feats)),
        "mixture" => Arc::new(Mixture::new(vec![
            (0.6, Box::new(FeatureBased::sqrt(feats.clone())) as Box<dyn BatchedDivergence>),
            (0.4, Box::new(FacilityLocation::from_features(&feats))),
        ])),
        other => panic!("unknown objective kind {other}"),
    }
}

#[test]
fn coordinator_ss_bitwise_matches_reference() {
    let (f, _) = day_feats(800, 1);
    let f = Arc::new(f);
    let reference = CpuBackend::new(f.as_ref());
    let params = SsParams::default().with_seed(33);
    let want = sparsify(&reference, &params);

    for threads in [1usize, 2, 4] {
        let pool = Arc::new(ThreadPool::new(threads, 16));
        let metrics = Arc::new(Metrics::new());
        let backend =
            ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, metrics).unwrap();
        let got = sparsify(&backend, &params);
        assert_eq!(got.kept, want.kept, "threads={threads}: parallel SS must be deterministic");
        assert_eq!(got.rounds, want.rounds);
    }
}

/// Property: `sparsify` honors `DivergenceBackend` determinism across
/// objective types — same seed ⇒ identical `kept` for `CpuBackend` vs
/// `ShardedBackend`, for facility location and mixtures, not just the
/// feature-based objective.
#[test]
fn sharded_ss_deterministic_for_every_objective_kind() {
    for kind in ["features", "facility", "mixture"] {
        for seed in [3u64, 17, 91] {
            let f = objective_instance(kind, 320, seed);
            let reference = CpuBackend::new(f.as_ref());
            let params = SsParams::default().with_seed(seed);
            let want = sparsify(&reference, &params);
            assert!(want.kept.len() < 320, "{kind}/{seed}: SS must prune");
            for threads in [1usize, 3] {
                for shards in [1usize, 7] {
                    let pool = Arc::new(ThreadPool::new(threads, 16));
                    let metrics = Arc::new(Metrics::new());
                    let backend = ShardedBackend::new(
                        Arc::clone(&f),
                        pool,
                        Compute::Cpu,
                        metrics,
                    )
                    .unwrap()
                    .with_shards(shards);
                    let got = sparsify(&backend, &params);
                    assert_eq!(
                        got.kept, want.kept,
                        "{kind}/seed={seed}/threads={threads}/shards={shards}: \
                         sharded SS must match the reference bit-for-bit"
                    );
                    assert_eq!(got.rounds, want.rounds);
                }
            }
        }
    }
}

/// Property (the tentpole invariant): the zero-allocation arena/write-into
/// round loop is bit-identical to the compiled-in fresh-allocation
/// reference — `kept` set, round count and measured ε̂ — across objective
/// kinds, shard counts, thread counts, sampling strategies and `min_keep`
/// floors, on both `CpuBackend` and `ShardedBackend`.
#[test]
fn arena_round_loop_bit_identical_to_reference_property() {
    use submodular_ss::util::prop::check_seeded;
    check_seeded(0x55AA, 20, |g| {
        let kind = *g.choose(&["features", "facility", "mixture"]);
        let n = g.usize_in(60, 260);
        let seed = g.usize_in(0, 1 << 16) as u64;
        let sampling = if g.bool() { Sampling::Uniform } else { Sampling::Importance };
        let min_keep = if g.bool() { g.usize_in(0, n) } else { 0 };
        let f = objective_instance(kind, n, seed);
        let params = SsParams { seed, sampling, min_keep, ..SsParams::default() };
        let candidates: Vec<usize> = (0..n).collect();

        let reference_backend = CpuBackend::new(f.as_ref());
        let want = sparsify_candidates_reference(&reference_backend, &candidates, &params);

        let got_cpu = sparsify_candidates(&reference_backend, &candidates, &params);
        assert_eq!(
            got_cpu.kept, want.kept,
            "{kind}/n={n}/seed={seed}/{sampling:?}/min_keep={min_keep}: CPU arena != reference"
        );
        assert_eq!(got_cpu.rounds, want.rounds);
        assert_eq!(got_cpu.divergence_evals, want.divergence_evals);
        assert_eq!(got_cpu.pruned_max_divergence, want.pruned_max_divergence);

        let threads = g.usize_in(1, 5);
        let shards = g.usize_in(1, 10);
        let pool = Arc::new(ThreadPool::new(threads, 16));
        let sharded =
            ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, Arc::new(Metrics::new()))
                .unwrap()
                .with_shards(shards);
        let got_sharded = sparsify_candidates(&sharded, &candidates, &params);
        assert_eq!(
            got_sharded.kept, want.kept,
            "{kind}/n={n}/seed={seed}/{sampling:?}/min_keep={min_keep}/threads={threads}/\
             shards={shards}: sharded arena != reference"
        );
        assert_eq!(got_sharded.rounds, want.rounds);
    });
}

/// Acceptance: the service summarizes every objective kind end-to-end
/// (submit → SS via `ShardedBackend` → lazy greedy → response), and the
/// result is bit-identical to the single-threaded reference pipeline.
#[test]
fn service_summarizes_every_objective_kind_matching_reference() {
    let svc = SummarizationService::start(
        ServiceConfig { workers: 2, queue_depth: 8, compute_threads: 2 },
        None,
    );
    let (n, k, seed) = (300usize, 10usize, 7u64);
    for kind in ["features", "facility", "mixture"] {
        let objective = match kind {
            "features" => Objective::Features(random_feats(n, 24, seed)),
            "facility" => {
                Objective::FacilityLocation(FacilityLocation::from_features(&random_feats(
                    n, 24, seed,
                )))
            }
            _ => {
                let feats = random_feats(n, 24, seed);
                Objective::Mixture(Mixture::new(vec![
                    (
                        0.6,
                        Box::new(FeatureBased::sqrt(feats.clone()))
                            as Box<dyn BatchedDivergence>,
                    ),
                    (0.4, Box::new(FacilityLocation::from_features(&feats))),
                ]))
            }
        };
        let params = SsParams::default().with_seed(seed);
        let resp = svc
            .submit(SummarizeRequest { objective, k, params: params.clone(), use_pjrt: false })
            .wait()
            .unwrap_or_else(|e| panic!("{kind}: service request failed: {e}"));

        let reference = objective_instance(kind, n, seed);
        let backend = CpuBackend::new(reference.as_ref());
        let ss = sparsify(&backend, &params);
        let sol = lazy_greedy(reference.as_submodular(), &ss.kept, k);
        assert_eq!(resp.n, n);
        assert_eq!(resp.reduced, ss.kept.len(), "{kind}: |V'| mismatch");
        assert_eq!(resp.ss_rounds, ss.rounds, "{kind}: round count mismatch");
        assert_eq!(resp.summary, sol.set, "{kind}: summary must match the reference");
        assert_eq!(resp.value, sol.value, "{kind}: value must match bit-for-bit");
        assert!(resp.value > 0.0);
    }
}

#[test]
fn service_under_concurrent_load() {
    let svc = SummarizationService::start(
        ServiceConfig { workers: 4, queue_depth: 8, compute_threads: 2 },
        None,
    );
    let g = NewsGenerator::new(
        CorpusParams { vocab_size: 600, d: 64, ..Default::default() },
        9,
    );
    // submit from multiple client threads simultaneously
    let svc = Arc::new(svc);
    let mut clients = Vec::new();
    for c in 0..3u64 {
        let svc2 = Arc::clone(&svc);
        let day = g.day(200 + 100 * c as usize, 0, c);
        clients.push(std::thread::spawn(move || {
            let mut values = Vec::new();
            for i in 0..4 {
                let resp = svc2
                    .submit(SummarizeRequest::features(
                        day.feats.clone(),
                        day.k,
                        SsParams::default().with_seed(i),
                    ))
                    .wait()
                    .unwrap();
                assert_eq!(resp.n, 200 + 100 * c as usize, "cross-request routing corruption");
                values.push(resp.value);
            }
            values
        }));
    }
    for cl in clients {
        let values = cl.join().unwrap();
        assert_eq!(values.len(), 4);
        assert!(values.iter().all(|&v| v > 0.0));
    }
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.get("completed").unwrap().as_f64(), Some(12.0));
}

#[test]
fn pruned_pipeline_quality_through_coordinator() {
    let (f, k) = day_feats(1200, 5);
    let f = Arc::new(f);
    let all: Vec<usize> = (0..1200).collect();
    let full = lazy_greedy(f.as_ref(), &all, k);

    let pool = Arc::new(ThreadPool::new(2, 16));
    let metrics = Arc::new(Metrics::new());
    let backend = ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, Arc::clone(&metrics))
        .unwrap();
    let ss = sparsify(&backend, &SsParams::default().with_seed(2));
    let reduced = lazy_greedy(f.as_ref(), &ss.kept, k);
    assert!(
        reduced.value / full.value > 0.9,
        "coordinator pipeline rel-utility: {}",
        reduced.value / full.value
    );
    assert!(
        metrics.counters.divergence_evals.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "metrics must record divergence work"
    );
}
