//! Integration: the parallel coordinator must reproduce the
//! single-threaded SS reference exactly, and the service must survive
//! concurrent load with correct routing.

use std::sync::Arc;

use submodular_ss::algorithms::{lazy_greedy, sparsify, CpuBackend, SsParams};
use submodular_ss::coordinator::{
    Compute, Metrics, ServiceConfig, ShardedBackend, SummarizationService, SummarizeRequest,
};
use submodular_ss::data::{CorpusParams, NewsGenerator};
use submodular_ss::submodular::FeatureBased;
use submodular_ss::util::pool::ThreadPool;

fn day_feats(n: usize, seed: u64) -> (FeatureBased, usize) {
    let g = NewsGenerator::new(
        CorpusParams { vocab_size: 800, d: 64, ..Default::default() },
        seed,
    );
    let day = g.day(n, 0, seed);
    (FeatureBased::sqrt(day.feats.clone()), day.k)
}

#[test]
fn coordinator_ss_bitwise_matches_reference() {
    let (f, _) = day_feats(800, 1);
    let f = Arc::new(f);
    let reference = CpuBackend::new(f.as_ref());
    let params = SsParams::default().with_seed(33);
    let want = sparsify(&reference, &params);

    for threads in [1usize, 2, 4] {
        let pool = Arc::new(ThreadPool::new(threads, 16));
        let metrics = Arc::new(Metrics::new());
        let backend =
            ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, metrics).unwrap();
        let got = sparsify(&backend, &params);
        assert_eq!(got.kept, want.kept, "threads={threads}: parallel SS must be deterministic");
        assert_eq!(got.rounds, want.rounds);
    }
}

#[test]
fn service_under_concurrent_load() {
    let svc = SummarizationService::start(
        ServiceConfig { workers: 4, queue_depth: 8, compute_threads: 2 },
        None,
    );
    let g = NewsGenerator::new(
        CorpusParams { vocab_size: 600, d: 64, ..Default::default() },
        9,
    );
    // submit from multiple client threads simultaneously
    let svc = Arc::new(svc);
    let mut clients = Vec::new();
    for c in 0..3u64 {
        let svc2 = Arc::clone(&svc);
        let day = g.day(200 + 100 * c as usize, 0, c);
        clients.push(std::thread::spawn(move || {
            let mut values = Vec::new();
            for i in 0..4 {
                let resp = svc2
                    .submit(SummarizeRequest {
                        feats: day.feats.clone(),
                        k: day.k,
                        params: SsParams::default().with_seed(i),
                        use_pjrt: false,
                    })
                    .wait()
                    .unwrap();
                assert_eq!(resp.n, 200 + 100 * c as usize, "cross-request routing corruption");
                values.push(resp.value);
            }
            values
        }));
    }
    for cl in clients {
        let values = cl.join().unwrap();
        assert_eq!(values.len(), 4);
        assert!(values.iter().all(|&v| v > 0.0));
    }
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.get("completed").unwrap().as_f64(), Some(12.0));
}

#[test]
fn pruned_pipeline_quality_through_coordinator() {
    let (f, k) = day_feats(1200, 5);
    let f = Arc::new(f);
    let all: Vec<usize> = (0..1200).collect();
    let full = lazy_greedy(f.as_ref(), &all, k);

    let pool = Arc::new(ThreadPool::new(2, 16));
    let metrics = Arc::new(Metrics::new());
    let backend = ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, Arc::clone(&metrics))
        .unwrap();
    let ss = sparsify(&backend, &SsParams::default().with_seed(2));
    let reduced = lazy_greedy(f.as_ref(), &ss.kept, k);
    assert!(
        reduced.value / full.value > 0.9,
        "coordinator pipeline rel-utility: {}",
        reduced.value / full.value
    );
    assert!(
        metrics.counters.divergence_evals.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "metrics must record divergence work"
    );
}
