//! Integration: the paper's theoretical statements, checked empirically on
//! instances small enough to brute-force or measure exactly.

use submodular_ss::algorithms::{
    brute_force, greedy, lazy_greedy, sparsify, CpuBackend, SsParams,
};
use submodular_ss::graph::SubmodularityGraph;
use submodular_ss::submodular::{FeatureBased, SparsificationObjective, SubmodularFn};
use submodular_ss::util::prop::check_seeded;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

fn instance(n: usize, d: usize, seed: u64) -> FeatureBased {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() * 2.0 } else { 0.0 };
        }
    }
    FeatureBased::sqrt(m)
}

/// Theorem 1: greedy restricted to a pruned V' with max divergence ε loses
/// at most (1 − 1/e)·kε vs the (1 − 1/e)-scaled optimum.
#[test]
fn theorem1_bound_against_brute_force() {
    check_seeded(41, 12, |g| {
        let n = 14;
        let k = 1 + g.usize_in(0, 4);
        let f = instance(n, 4, g.usize_in(0, 1 << 30) as u64);
        let graph = SubmodularityGraph::new(&f);
        // choose an arbitrary V' and compute its exact eps = max over pruned
        // v of w_{V'v}
        let vprime = g.subset(n, k..n);
        if vprime.len() < k {
            return;
        }
        let eps = (0..n)
            .filter(|v| !vprime.contains(v))
            .map(|v| graph.divergence(&vprime, v))
            .fold(0.0f64, f64::max);
        let opt = brute_force(&f, &(0..n).collect::<Vec<_>>(), k);
        let s_pruned = greedy(&f, &vprime, k);
        let bound = (1.0 - (-1.0f64).exp()) * (opt.value - k as f64 * eps);
        assert!(
            s_pruned.value >= bound - 1e-9,
            "Theorem 1 violated: f(S')={} < {bound} (eps={eps}, k={k})",
            s_pruned.value
        );
    });
}

/// Theorem 2 (empirical form): SS's measured ε̂ certifies the bound
/// f(S') ≥ (1 − 1/e)(f(S*) − 2kε̂), with f(S*) brute-forced.
#[test]
fn theorem2_bound_with_ss_epsilon() {
    for seed in 0..6u64 {
        let n = 16;
        let k = 3;
        let f = instance(n, 4, seed);
        let backend = CpuBackend::new(&f);
        // r=1 so that SS actually prunes at tiny n
        let params = SsParams { r: 1, ..SsParams::default().with_seed(seed) };
        let ss = sparsify(&backend, &params);
        if ss.kept.len() < k || ss.kept.len() == n {
            continue;
        }
        let opt = brute_force(&f, &(0..n).collect::<Vec<_>>(), k);
        let sol = greedy(&f, &ss.kept, k);
        let eps_hat = ss.pruned_max_divergence.max(0.0);
        let bound = (1.0 - (-1.0f64).exp()) * (opt.value - 2.0 * k as f64 * eps_hat);
        assert!(
            sol.value >= bound - 1e-9,
            "seed {seed}: f(S')={} < {bound} (eps-hat {eps_hat})",
            sol.value
        );
    }
}

/// Proposition 1: h of Eq. (9) built from *real* submodularity-graph weights
/// is non-monotone submodular (diminishing returns verified on the nose).
#[test]
fn proposition1_h_submodular_on_real_weights() {
    let f = instance(12, 5, 7);
    let graph = SubmodularityGraph::new(&f);
    let eps = 0.25;
    let h = SparsificationObjective::from_weights(12, eps, |u, v| graph.weight(u, v));
    check_seeded(43, 120, |g| {
        let b = g.subset(12, 0..8);
        let a: Vec<usize> = b.iter().copied().filter(|_| g.bool()).collect();
        let outside: Vec<usize> = (0..12).filter(|x| !b.contains(x)).collect();
        if outside.is_empty() {
            return;
        }
        let v = outside[g.usize_in(0, outside.len())];
        let ga = h.eval(&[a.clone(), vec![v]].concat()) - h.eval(&a);
        let gb = h.eval(&[b.clone(), vec![v]].concat()) - h.eval(&b);
        assert!(ga >= gb - 1e-9, "h not submodular: {ga} < {gb}");
    });
    // non-monotone: the full set scores |V| - |V| = 0 < best singleton-ish sets
    let full: Vec<usize> = (0..12).collect();
    assert_eq!(h.eval(&full), 0.0);
}

/// Lemma 3 on every objective family we ship (triangle inequality is the
/// load-bearing fact for Lemma 4 / Prop. 2).
#[test]
fn lemma3_across_objectives() {
    use submodular_ss::submodular::{FacilityLocation, Modular, SetCover};
    let mut rng = Rng::new(9);
    let n = 9;

    let feature = instance(n, 4, 1);
    let mut sim = vec![0.0f32; n * n];
    for i in 0..n {
        sim[i * n + i] = 1.0;
        for u in (i + 1)..n {
            let s = rng.f32();
            sim[i * n + u] = s;
            sim[u * n + i] = s;
        }
    }
    let fl = FacilityLocation::new(n, sim);
    let sc = SetCover::unit(
        (0..n).map(|i| vec![i as u32, ((i + 1) % n) as u32, ((i * 3) % n) as u32]).collect(),
        n,
    );
    let md = Modular::new((0..n).map(|i| i as f64).collect());

    let objectives: Vec<&dyn SubmodularFn> = vec![&feature, &fl, &sc, &md];
    for (oi, f) in objectives.into_iter().enumerate() {
        let g = SubmodularityGraph::new(f);
        for v in 0..n {
            for u in 0..n {
                for x in 0..n {
                    if v == u || u == x || v == x {
                        continue;
                    }
                    assert!(
                        g.weight(v, x) <= g.weight(v, u) + g.weight(u, x) + 1e-6,
                        "objective {oi}: triangle inequality violated at ({v},{u},{x})"
                    );
                }
            }
        }
    }
}

/// Paper's headline empirical claim at test scale: SS + lazy greedy tracks
/// lazy greedy within a few percent while reducing the ground set ≥ 4×.
#[test]
fn headline_quality_and_reduction() {
    let g = submodular_ss::data::NewsGenerator::new(
        submodular_ss::data::CorpusParams { vocab_size: 1000, d: 128, ..Default::default() },
        3,
    );
    let day = g.day(2500, 0, 3);
    let f = FeatureBased::sqrt(day.feats.clone());
    let all: Vec<usize> = (0..f.n()).collect();
    let full = lazy_greedy(&f, &all, day.k);
    let backend = CpuBackend::new(&f);
    let ss = sparsify(&backend, &SsParams::default().with_seed(4));
    let sol = lazy_greedy(&f, &ss.kept, day.k);
    assert!(ss.kept.len() * 4 <= 2500, "reduction ≥ 4×: |V'|={}", ss.kept.len());
    assert!(sol.value / full.value > 0.95, "rel utility {}", sol.value / full.value);
}
