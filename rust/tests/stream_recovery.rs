//! Crash-exact recovery contract tests for durable streaming sessions.
//!
//! The durability design is log-before-apply: an admitted batch hits the
//! write-ahead log before the session mutates, eviction decisions are
//! logged after each windowed re-sparsification, and checkpoints cover
//! (and truncate) the log. The contract pinned here:
//!
//! 1. **Kill-point sweep** — for *every* mutating-store operation a crash
//!    could land after, the session recovered from what survived is
//!    **bit-identical** to an uninterrupted session fed the durable
//!    prefix: same lifetime accounting, same external-id → row mapping,
//!    same sieve state, same Final-snapshot summary and f64 value bits —
//!    across objectives (feature-based with and without the admission
//!    filter, dense facility location, sparse facility location whose
//!    post-eviction neighbor history must come back from the checkpoint).
//!    And the recovered session keeps streaming: feeding the remaining
//!    batches to both yields identical finals.
//! 2. **Torn tails** are truncated (once, counted), never fatal.
//! 3. **Checksum corruption** (WAL or checkpoint) reports a typed
//!    `Rejected` — recovery never panics on a damaged store.
//! 4. The replayed WAL tail is **bounded by the checkpoint interval**.
//! 5. A store that starts erroring **quarantines** the session: mutating
//!    calls reject typed, reads still work, nothing panics.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use submodular_ss::algorithms::SsParams;
use submodular_ss::coordinator::{Metrics, ServiceError};
use submodular_ss::stream::{
    DurabilityConfig, FaultStore, FileStore, MemStore, ObjectiveSpec, SieveParams, SnapshotMode,
    StreamConfig, StreamSession,
};
use submodular_ss::submodular::{BuildStrategy, Concave};
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

fn rows(n: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.35) { rng.f32() } else { 0.0 };
        }
    }
    m
}

fn pool() -> Arc<ThreadPool> {
    Arc::new(ThreadPool::new(2, 16))
}

fn fresh(kind: ObjectiveSpec, d: usize, cfg: StreamConfig) -> StreamSession {
    StreamSession::new(kind, d, cfg, pool(), Arc::new(Metrics::new())).unwrap()
}

/// Full bit-exactness check: accounting, id → row mapping, and the exact
/// Final snapshot (summary ids + f64 value bits, which transitively pins
/// retained rows, buffer contents, sieve state and SS trajectory).
fn assert_identical(tag: &str, a: &mut StreamSession, b: &mut StreamSession) {
    assert_eq!(a.stats(), b.stats(), "{tag}: lifetime accounting diverged");
    assert_eq!(a.remap().assigned(), b.remap().assigned(), "{tag}: assigned ids diverged");
    for ext in 0..a.remap().assigned() {
        assert_eq!(a.row(ext), b.row(ext), "{tag}: row for ext id {ext} diverged");
    }
    if a.stats().live == 0 {
        return; // nothing durable survived; nothing to summarize
    }
    let sa = a.snapshot_summary(SnapshotMode::Final).unwrap();
    let sb = b.snapshot_summary(SnapshotMode::Final).unwrap();
    assert_eq!(sa.summary, sb.summary, "{tag}: snapshot summaries diverged");
    assert_eq!(sa.value.to_bits(), sb.value.to_bits(), "{tag}: snapshot value bits diverged");
    assert_eq!(sa.ss_rounds, sb.ss_rounds, "{tag}: snapshot SS trajectory diverged");
}

/// Run the scenario uninterrupted once to enumerate the mutating-store
/// operations, then re-run it against a store that drops everything after
/// op `kill` — for every `kill` — and check the recovered session is
/// bit-identical to an oracle fed exactly the batches whose WAL record
/// landed, both at recovery and after the stream continues.
fn kill_sweep(name: &str, kind: ObjectiveSpec, d: usize, cfg: &StreamConfig, batches: &[FeatureMatrix]) {
    let dcfg = DurabilityConfig::default().with_checkpoint_interval(4);

    // --- probe run: where does each batch's WAL write land in op order? ---
    let probe = FaultStore::new(Box::new(MemStore::new()));
    let ops = probe.ops_counter();
    let mut session = StreamSession::open_durable(
        kind,
        d,
        cfg.clone(),
        pool(),
        Arc::new(Metrics::new()),
        Box::new(probe),
        dcfg,
    )
    .unwrap();
    let mut pre = Vec::with_capacity(batches.len());
    for b in batches {
        // the batch's log-before-apply WAL append is the next mutating op
        pre.push(ops.load(Ordering::SeqCst));
        session.append(b.data()).unwrap();
    }
    let total_ops = ops.load(Ordering::SeqCst);
    if cfg.admission.is_none() {
        // (with the sieve filter on, eviction depends on the admission rate
        // — the sweep still pins whatever trajectory the data produces)
        assert!(session.stats().evicted > 0, "{name}: scenario must exercise eviction");
    }
    drop(session);

    for kill in 0..=total_ops {
        let tag = format!("{name}/kill={kill}");
        let surviving = MemStore::new();
        let fault = FaultStore::new(Box::new(surviving.clone())).fail_after(kill);
        let mut doomed = StreamSession::open_durable(
            kind,
            d,
            cfg.clone(),
            pool(),
            Arc::new(Metrics::new()),
            Box::new(fault),
            dcfg,
        )
        .unwrap();
        for b in batches {
            let _ = doomed.append(b.data());
        }
        drop(doomed); // crash: whatever reached `surviving` is all that's left

        let recovered = StreamSession::recover_with_report(
            pool(),
            Arc::new(Metrics::new()),
            Box::new(surviving.clone()),
            dcfg,
        );
        if kill == 0 {
            // even the open checkpoint never landed: typed, not a panic
            match recovered {
                Err(ServiceError::Rejected { reason }) => {
                    assert!(reason.contains("recovery failed"), "{tag}: {reason}");
                }
                Ok(_) => panic!("{tag}: recovery without any checkpoint must fail typed"),
                Err(other) => panic!("{tag}: expected Rejected, got {other:?}"),
            }
            continue;
        }
        let (mut rec, report) =
            recovered.unwrap_or_else(|e| panic!("{tag}: recovery failed: {e}"));
        assert_eq!(report.torn_tail_truncations, 0, "{tag}: whole-record drops tear nothing");

        // batch j is durable iff its WAL write (op pre[j]) was within budget
        let durable_prefix = pre.iter().filter(|&&p| p < kill).count();
        let mut oracle = fresh(kind, d, cfg.clone());
        for b in &batches[..durable_prefix] {
            oracle.append(b.data()).unwrap();
        }
        assert_identical(&tag, &mut rec, &mut oracle);

        // the recovered session keeps streaming, in lockstep with the oracle
        for b in &batches[durable_prefix..] {
            let ra = rec.append(b.data()).unwrap();
            let oa = oracle.append(b.data()).unwrap();
            assert_eq!(ra.first_ext, oa.first_ext, "{tag}: id assignment diverged post-recovery");
        }
        assert_identical(&format!("{tag}/continued"), &mut rec, &mut oracle);
    }
}

#[test]
fn every_kill_point_recovers_bit_identical_features() {
    let d = 6;
    let cfg = StreamConfig::new(4)
        .with_ss(SsParams::default().with_seed(3).with_min_keep(8))
        .with_high_water(48);
    let batches: Vec<FeatureMatrix> = (0..6).map(|i| rows(36, d, 100 + i)).collect();
    kill_sweep("features", ObjectiveSpec::Features(Concave::Sqrt), d, &cfg, &batches);
}

#[test]
fn every_kill_point_recovers_bit_identical_features_with_sieve_filter() {
    let d = 6;
    let cfg = StreamConfig::new(4)
        .with_ss(SsParams::default().with_seed(5).with_min_keep(8))
        .with_high_water(40)
        .with_admission(SieveParams::paper_default());
    let batches: Vec<FeatureMatrix> = (0..6).map(|i| rows(36, d, 200 + i)).collect();
    kill_sweep("features+sieve", ObjectiveSpec::Features(Concave::Sqrt), d, &cfg, &batches);
}

#[test]
fn every_kill_point_recovers_bit_identical_dense_facility_location() {
    let d = 6;
    let cfg = StreamConfig::new(4)
        .with_ss(SsParams::default().with_seed(7).with_min_keep(8))
        .with_high_water(40);
    let batches: Vec<FeatureMatrix> = (0..5).map(|i| rows(24, d, 300 + i)).collect();
    kill_sweep("facility-dense", ObjectiveSpec::FacilityLocation, d, &cfg, &batches);
}

#[test]
fn every_kill_point_recovers_bit_identical_sparse_facility_location() {
    // crossover 0 forces the sparse top-t store from the first row; its
    // neighbor lists carry post-eviction history that only the checkpoint
    // can restore (retained rows alone rebuild a *different* store than
    // one grown through the eviction sequence)
    let d = 6;
    let cfg = StreamConfig::new(4)
        .with_ss(SsParams::default().with_seed(9).with_min_keep(8))
        .with_high_water(40);
    let kind =
        ObjectiveSpec::FacilityLocationSparse { t: 8, crossover: 0, build: BuildStrategy::Auto };
    let batches: Vec<FeatureMatrix> = (0..5).map(|i| rows(24, d, 400 + i)).collect();
    kill_sweep("facility-sparse", kind, d, &cfg, &batches);
}

#[test]
fn torn_wal_tail_is_truncated_once_and_counted() {
    let d = 6;
    let kind = ObjectiveSpec::Features(Concave::Sqrt);
    // full window (no compaction records) so the WAL holds exactly one
    // record per batch and the replay arithmetic below is exact
    let cfg = StreamConfig::new(4).with_ss(SsParams::default().with_seed(11));
    let dcfg = DurabilityConfig::default().with_checkpoint_interval(0); // keep the whole WAL
    let batches: Vec<FeatureMatrix> = (0..5).map(|i| rows(30, d, 500 + i)).collect();

    // probe for the op position of each batch's WAL write
    let probe = FaultStore::new(Box::new(MemStore::new()));
    let ops = probe.ops_counter();
    let mut session = StreamSession::open_durable(
        kind,
        d,
        cfg.clone(),
        pool(),
        Arc::new(Metrics::new()),
        Box::new(probe),
        dcfg,
    )
    .unwrap();
    let mut pre = Vec::new();
    for b in &batches {
        pre.push(ops.load(Ordering::SeqCst));
        session.append(b.data()).unwrap();
    }
    drop(session);

    // crash exactly at batch 3's WAL write, landing a 7-byte prefix of it
    let torn_at = 3;
    let surviving = MemStore::new();
    let fault = FaultStore::new(Box::new(surviving.clone()))
        .fail_after(pre[torn_at])
        .with_torn_tail(7);
    let mut doomed = StreamSession::open_durable(
        kind,
        d,
        cfg.clone(),
        pool(),
        Arc::new(Metrics::new()),
        Box::new(fault),
        dcfg,
    )
    .unwrap();
    for b in &batches {
        let _ = doomed.append(b.data());
    }
    drop(doomed);
    let wal_with_tear = surviving.len("wal");

    let metrics = Arc::new(Metrics::new());
    let (mut rec, report) = StreamSession::recover_with_report(
        pool(),
        Arc::clone(&metrics),
        Box::new(surviving.clone()),
        dcfg,
    )
    .unwrap();
    assert_eq!(report.torn_tail_truncations, 1, "exactly one torn tail");
    assert_eq!(report.replayed_records, torn_at as u64, "records before the tear replay");
    assert_eq!(
        metrics.counters.torn_tail_truncations.load(Ordering::Relaxed),
        1,
        "the truncation must be metered"
    );
    assert!(
        surviving.len("wal") < wal_with_tear,
        "recovery must truncate the torn bytes in place"
    );

    // recovered == oracle over the durable prefix (everything before the tear)
    let mut oracle = fresh(kind, d, cfg);
    for b in &batches[..torn_at] {
        oracle.append(b.data()).unwrap();
    }
    assert_identical("torn-tail", &mut rec, &mut oracle);

    // the truncated log is coherent: recovering again finds no tear
    drop(rec);
    let (_, again) = StreamSession::recover_with_report(
        pool(),
        Arc::new(Metrics::new()),
        Box::new(surviving),
        dcfg,
    )
    .unwrap();
    assert_eq!(again.torn_tail_truncations, 0);
}

#[test]
fn corrupt_wal_or_checkpoint_rejects_typed_never_panics() {
    let d = 6;
    let kind = ObjectiveSpec::Features(Concave::Sqrt);
    let cfg = StreamConfig::new(4).with_ss(SsParams::default().with_seed(13));
    let dcfg = DurabilityConfig::default().with_checkpoint_interval(0);
    let store = MemStore::new();
    let mut session = StreamSession::open_durable(
        kind,
        d,
        cfg,
        pool(),
        Arc::new(Metrics::new()),
        Box::new(store.clone()),
        dcfg,
    )
    .unwrap();
    for i in 0..3 {
        session.append(rows(20, d, 600 + i).data()).unwrap();
    }
    drop(session);

    // MemStore clones share blobs, so corrupt deep copies, not handles
    let deep_copy = |src: &MemStore| {
        let dst = MemStore::new();
        for name in ["wal", "checkpoint"] {
            if let Some(bytes) = src.raw(name) {
                dst.set_raw(name, bytes);
            }
        }
        dst
    };

    // flip a byte inside the first record's body (past the 4-byte length
    // prefix) — the checksum must catch it and quarantine, not panic
    let wal_broken = deep_copy(&store);
    wal_broken.flip_byte("wal", 14);
    match StreamSession::recover_with_report(
        pool(),
        Arc::new(Metrics::new()),
        Box::new(wal_broken.clone()),
        dcfg,
    ) {
        Err(ServiceError::Rejected { reason }) => {
            assert!(reason.contains("recovery failed"), "{reason}");
        }
        Ok(_) => panic!("corrupt WAL record must not recover silently"),
        Err(other) => panic!("expected Rejected, got {other:?}"),
    }
    // a corrupt record is quarantined, not destroyed: the bytes are left
    // for forensics (unlike a torn tail, which is truncated)
    assert_eq!(wal_broken.len("wal"), store.len("wal"));

    // corrupt checkpoint: same typed shape
    let ckpt_broken = deep_copy(&store);
    ckpt_broken.flip_byte("checkpoint", 12);
    match StreamSession::recover_with_report(
        pool(),
        Arc::new(Metrics::new()),
        Box::new(ckpt_broken),
        dcfg,
    ) {
        Err(ServiceError::Rejected { reason }) => {
            assert!(reason.contains("recovery failed"), "{reason}");
        }
        Ok(_) => panic!("corrupt checkpoint must not recover silently"),
        Err(other) => panic!("expected Rejected, got {other:?}"),
    }

    // the pristine store still recovers fine
    assert!(StreamSession::recover_with_report(
        pool(),
        Arc::new(Metrics::new()),
        Box::new(store),
        dcfg,
    )
    .is_ok());
}

#[test]
fn replayed_wal_tail_is_bounded_by_the_checkpoint_interval() {
    let d = 4;
    let kind = ObjectiveSpec::Features(Concave::Sqrt);
    // full window: no compaction records, so the arithmetic is exact
    let cfg = StreamConfig::new(3).with_ss(SsParams::default().with_seed(15));
    let interval = 4u64;
    let dcfg = DurabilityConfig::default().with_checkpoint_interval(interval);
    let store = MemStore::new();
    let mut session = StreamSession::open_durable(
        kind,
        d,
        cfg,
        pool(),
        Arc::new(Metrics::new()),
        Box::new(store.clone()),
        dcfg,
    )
    .unwrap();
    let n_batches = 14u64; // 14 ≡ 2 (mod 4): two records past the last auto-checkpoint
    for i in 0..n_batches {
        session.append(rows(10, d, 700 + i).data()).unwrap();
    }
    drop(session); // crash without close

    let (_, report) = StreamSession::recover_with_report(
        pool(),
        Arc::new(Metrics::new()),
        Box::new(store),
        dcfg,
    )
    .unwrap();
    assert_eq!(report.replayed_records, n_batches % interval);
    assert!(report.replayed_records <= interval, "replay must be bounded by the interval");
    assert_eq!(report.checkpoint_seq, n_batches - n_batches % interval);
}

#[test]
fn graceful_close_recovers_as_a_closed_session() {
    let d = 5;
    let kind = ObjectiveSpec::Features(Concave::Sqrt);
    let cfg = StreamConfig::new(3).with_ss(SsParams::default().with_seed(17));
    let dcfg = DurabilityConfig::default();
    let store = MemStore::new();
    let mut session = StreamSession::open_durable(
        kind,
        d,
        cfg,
        pool(),
        Arc::new(Metrics::new()),
        Box::new(store.clone()),
        dcfg,
    )
    .unwrap();
    session.append(rows(40, d, 800).data()).unwrap();
    let stats = session.close();
    drop(session);

    let (mut rec, _) = StreamSession::recover_with_report(
        pool(),
        Arc::new(Metrics::new()),
        Box::new(store),
        dcfg,
    )
    .unwrap();
    assert_eq!(rec.stats(), stats, "closed-session accounting must survive recovery");
    match rec.append(rows(5, d, 801).data()) {
        Err(ServiceError::ServiceDown) => {}
        other => panic!("a recovered closed session must shed appends, got {other:?}"),
    }
}

#[test]
fn store_io_errors_quarantine_the_session_typed() {
    let d = 5;
    let kind = ObjectiveSpec::Features(Concave::Sqrt);
    let cfg = StreamConfig::new(3).with_ss(SsParams::default().with_seed(19));
    let dcfg = DurabilityConfig::default().with_checkpoint_interval(0);
    // the open checkpoint takes 2 ops; the first batch takes 1; the disk
    // "fails" at the second batch's WAL write
    let fault = FaultStore::new(Box::new(MemStore::new())).fail_after(3).with_error_on_fault();
    let mut session = StreamSession::open_durable(
        kind,
        d,
        cfg,
        pool(),
        Arc::new(Metrics::new()),
        Box::new(fault),
        dcfg,
    )
    .unwrap();
    session.append(rows(20, d, 900).data()).unwrap();
    let before = session.stats();

    match session.append(rows(20, d, 901).data()) {
        Err(ServiceError::Rejected { reason }) => {
            assert!(reason.contains("injected fault"), "{reason}");
        }
        other => panic!("a failed WAL write must reject the batch typed, got {other:?}"),
    }
    // log-before-apply: the rejected batch left no trace in memory
    assert_eq!(session.stats(), before, "a rejected batch must not mutate the session");

    // quarantine is sticky across every mutating call…
    match session.append(rows(20, d, 902).data()) {
        Err(ServiceError::Rejected { reason }) => {
            assert!(reason.contains("quarantined"), "{reason}");
        }
        other => panic!("a quarantined session must stay rejected, got {other:?}"),
    }
    match session.checkpoint_now() {
        Err(ServiceError::Rejected { reason }) => {
            assert!(reason.contains("quarantined"), "{reason}");
        }
        other => panic!("a quarantined session must refuse checkpoints, got {other:?}"),
    }
    // …while reads still work: the in-memory state is intact
    let snap = session.snapshot_summary(SnapshotMode::Final).unwrap();
    assert_eq!(snap.live, before.live);
    assert!(snap.value > 0.0);
}

#[test]
fn file_store_round_trip_with_temp_dir_hygiene() {
    let d = 6;
    let kind = ObjectiveSpec::Features(Concave::Sqrt);
    let cfg = StreamConfig::new(4)
        .with_ss(SsParams::default().with_seed(21).with_min_keep(8))
        .with_high_water(48);
    let dcfg = DurabilityConfig::default().with_checkpoint_interval(4);
    let dir = std::env::temp_dir().join(format!("ss_stream_recovery_{}", std::process::id()));
    let batches: Vec<FeatureMatrix> = (0..4).map(|i| rows(30, d, 950 + i)).collect();

    let result = std::panic::catch_unwind(|| {
        let store = FileStore::open(&dir).unwrap();
        let mut session = StreamSession::open_durable(
            kind,
            d,
            cfg.clone(),
            pool(),
            Arc::new(Metrics::new()),
            Box::new(store),
            dcfg,
        )
        .unwrap();
        for b in &batches {
            session.append(b.data()).unwrap();
        }
        drop(session); // crash: only the files remain

        let (mut rec, _) = StreamSession::recover_with_report(
            pool(),
            Arc::new(Metrics::new()),
            Box::new(FileStore::open(&dir).unwrap()),
            dcfg,
        )
        .unwrap();
        let mut oracle = fresh(kind, d, cfg.clone());
        for b in &batches {
            oracle.append(b.data()).unwrap();
        }
        assert_identical("file-store", &mut rec, &mut oracle);
    });
    // temp-dir hygiene: remove our directory whether the body passed or not
    let _ = std::fs::remove_dir_all(&dir);
    if let Err(p) = result {
        std::panic::resume_unwind(p);
    }
}
