//! Streaming ↔ batch contract tests.
//!
//! 1. **Equivalence** — a `StreamSession` whose window covers the entire
//!    stream (never re-sparsifies) with the admission filter disabled must
//!    produce the **bit-identical** summary to the batch
//!    `ss_then_greedy` pipeline over the same ground set: same kept-set
//!    SS pass, same lazy-greedy commits, same f64 value bits — across
//!    objectives, shard counts, batch chunkings and seeds.
//! 2. **Remap round-trip** — external ids stay stable (and resolve to the
//!    exact original rows) across ≥ 3 windowed re-sparsifications, and
//!    evicted ids stay dead.

use std::sync::Arc;

use submodular_ss::algorithms::{ss_then_greedy, CpuBackend, SsParams};
use submodular_ss::coordinator::Metrics;
use submodular_ss::stream::{ObjectiveSpec, SnapshotMode, StreamConfig, StreamSession};
use submodular_ss::submodular::{
    BatchedDivergence, BuildStrategy, Concave, FacilityLocation, FeatureBased,
};
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

fn rows(n: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.35) { rng.f32() } else { 0.0 };
        }
    }
    m
}

fn batch_objective(kind: ObjectiveSpec, data: &FeatureMatrix) -> Box<dyn BatchedDivergence> {
    match kind {
        ObjectiveSpec::Features(g) => Box::new(FeatureBased::new(data.clone(), g)),
        ObjectiveSpec::FacilityLocation => Box::new(FacilityLocation::from_features(data)),
        ObjectiveSpec::FacilityLocationSparse { t, crossover, build } => {
            Box::new(FacilityLocation::from_features_strat(
                data,
                crossover as usize,
                if t == 0 { None } else { Some(t as usize) },
                build,
                None,
            ))
        }
    }
}

fn stream_session(
    kind: ObjectiveSpec,
    d: usize,
    cfg: StreamConfig,
    threads: usize,
) -> StreamSession {
    StreamSession::new(
        kind,
        d,
        cfg,
        Arc::new(ThreadPool::new(threads, 16)),
        Arc::new(Metrics::new()),
    )
    .unwrap()
}

#[test]
fn full_window_filter_off_stream_is_bit_identical_to_batch() {
    let objectives = [
        ("features-sqrt", ObjectiveSpec::Features(Concave::Sqrt)),
        ("features-log1p", ObjectiveSpec::Features(Concave::Log1p)),
        ("facility", ObjectiveSpec::FacilityLocation),
        // forced-sparse store: the stream builds it pooled, the batch
        // oracle serially — pinning that the store build is deterministic
        // either way and the truncated objective streams bit-identically
        (
            "facility-sparse",
            ObjectiveSpec::FacilityLocationSparse {
                t: 20,
                crossover: 0,
                build: BuildStrategy::Auto,
            },
        ),
    ];
    let d = 10;
    let k = 7;
    for (name, kind) in objectives {
        // facility location's n² sim matrix keeps its leg smaller
        let n = if matches!(kind, ObjectiveSpec::Features(_)) { 380 } else { 220 };
        for shards in [1usize, 7] {
            for seed in [0u64, 11, 42] {
                let data = rows(n, d, seed.wrapping_add(1000));
                let params = SsParams::default().with_seed(seed);

                // --- batch oracle: the paper pipeline over the full set ---
                let f = batch_objective(kind, &data);
                let backend = CpuBackend::new(f.as_ref());
                let (ss, sol) = ss_then_greedy(f.as_submodular(), &backend, k, &params);

                // --- stream: same rows appended in uneven chunks ---
                let cfg = StreamConfig::new(k).with_ss(params.clone()).with_shards(shards);
                let mut sess = stream_session(kind, d, cfg, 3);
                // ragged chunk sizes exercise batching without changing
                // arrival order
                for chunk in data.data().chunks(d * 73) {
                    sess.append(chunk).unwrap();
                }
                assert_eq!(sess.live(), n);
                let snap = sess.snapshot_summary(SnapshotMode::Final).unwrap();

                assert_eq!(
                    snap.summary, sol.set,
                    "{name}/shards={shards}/seed={seed}: stream summary diverged from batch"
                );
                assert_eq!(
                    snap.value.to_bits(),
                    sol.value.to_bits(),
                    "{name}/shards={shards}/seed={seed}: value must be bit-identical"
                );
                assert_eq!(snap.ss_rounds, ss.rounds, "same SS trajectory");
                assert_eq!(snap.live, n);

                // chunking must not matter either: one giant append
                let mut sess2 = stream_session(
                    kind,
                    d,
                    StreamConfig::new(k).with_ss(params.clone()).with_shards(shards),
                    2,
                );
                sess2.append(data.data()).unwrap();
                let snap2 = sess2.snapshot_summary(SnapshotMode::Final).unwrap();
                assert_eq!(snap2.summary, snap.summary, "{name}: chunking changed the result");
                assert_eq!(snap2.value.to_bits(), snap.value.to_bits());
            }
        }
    }
}

#[test]
fn external_ids_roundtrip_across_three_or_more_resparsifications() {
    let d = 8;
    let n = 1500;
    let data = rows(n, d, 77);
    let cfg = StreamConfig::new(6)
        .with_ss(SsParams::default().with_seed(5).with_min_keep(12))
        .with_high_water(150);
    let mut sess = stream_session(ObjectiveSpec::Features(Concave::Sqrt), d, cfg, 2);
    let mut total_resparsifies = 0usize;
    for chunk in data.data().chunks(d * 200) {
        total_resparsifies += sess.append(chunk).unwrap().resparsifies;
    }
    assert!(
        total_resparsifies >= 3,
        "need ≥3 re-sparsifications to exercise the remap, got {total_resparsifies}"
    );
    assert_eq!(sess.stats().windows as usize, total_resparsifies);
    assert_eq!(sess.stats().assigned, n);

    // every live external id resolves to exactly its original row;
    // everything else is genuinely gone
    let mut live = 0usize;
    for ext in 0..n {
        match sess.row(ext) {
            Some(row) => {
                assert_eq!(row, data.row(ext), "ext {ext} drifted across re-sparsifications");
                live += 1;
            }
            None => assert!(sess.remap().internal(ext).is_none()),
        }
    }
    assert_eq!(live, sess.live());
    assert!(live < n, "evictions must actually have happened");

    // the remap is a bijection on the live set
    for int in 0..sess.live() {
        let ext = sess.remap().external(int);
        assert_eq!(sess.remap().internal(ext), Some(int));
    }

    // summaries speak external ids that resolve to live rows
    let snap = sess.snapshot_summary(SnapshotMode::Final).unwrap();
    assert_eq!(snap.summary.len(), 6);
    for &e in &snap.summary {
        assert!(sess.row(e).is_some());
    }

    // the forward map's dead prefix was compacted behind the base offset:
    // residue is bounded by the live id span, not the stream length
    let remap = sess.remap();
    assert!(remap.base() > 0, "≥3 windows must strand a compactable dead prefix");
    assert_eq!(remap.map_residue(), remap.assigned() - remap.base());
    assert!(
        remap.map_residue() < n,
        "residue {} must not cover the whole stream",
        remap.map_residue()
    );

    // ids keep flowing after the last compaction
    let more = rows(40, d, 78);
    let r = sess.append(more.data()).unwrap();
    assert_eq!(r.first_ext, n);
    assert_eq!(sess.row(n).unwrap(), more.row(0));
}

#[test]
fn service_stream_final_snapshot_matches_batch_pipeline() {
    use submodular_ss::coordinator::{ServiceConfig, SummarizationService};
    let d = 12;
    let n = 320;
    let k = 8;
    let data = rows(n, d, 9);
    let params = SsParams::default().with_seed(4);

    let f = FeatureBased::sqrt(data.clone());
    let backend = CpuBackend::new(&f);
    let (_ss, sol) = ss_then_greedy(&f, &backend, k, &params);

    let svc = SummarizationService::start(ServiceConfig::default(), None);
    let id = svc
        .open_stream(
            ObjectiveSpec::Features(Concave::Sqrt),
            d,
            StreamConfig::new(k).with_ss(params),
        )
        .unwrap();
    for chunk in data.data().chunks(d * 100) {
        svc.append(id, chunk).unwrap();
    }
    // snapshots are jobs now: the copy-on-snapshot pool job must still be
    // bit-identical to the batch pipeline
    let snap = svc.submit_snapshot(id, SnapshotMode::Final).unwrap().wait().unwrap();
    assert_eq!(snap.summary, sol.set);
    assert_eq!(snap.value.to_bits(), sol.value.to_bits());
    let stats = svc.close(id).unwrap();
    assert_eq!(stats.appends, n as u64);
    assert_eq!(stats.windows, 0, "full-window session never re-sparsifies");
}
