//! The engine's Minoux-exactness contract, end to end: batched lazy greedy
//! ≡ scalar lazy greedy ≡ naive greedy, across objectives (feature-based /
//! facility-location / mixture), gain routes (direct state kernels vs the
//! sharded backend), thread counts, and cohort sizes — with strictly fewer
//! kernel dispatches than the scalar oracle-call count on every instance.

use std::sync::Arc;

use submodular_ss::algorithms::{
    greedy_reference, lazy_greedy_reference, sparsify, ss_then_greedy,
    stochastic_greedy_reference, CpuBackend, GainRoute, MaximizerEngine, SsParams,
};
use submodular_ss::coordinator::{Compute, Metrics, ShardedBackend};
use submodular_ss::submodular::{BatchedDivergence, FacilityLocation, FeatureBased, Mixture};
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::prop::check_seeded;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

fn random_feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.4) { rng.f32() } else { 0.0 };
        }
        // guarantee a nonzero dim: an all-zero row gives facility location
        // degenerate unit-diagonal columns whose gains tie *exactly*, and
        // naive greedy (swap_remove-reordered scan) may order an exact tie
        // differently from lazy greedy (original-position heap ids) — a
        // property of tied instances, not an engine bug
        if m.row(i).iter().all(|&x| x == 0.0) {
            let j = i % d;
            m.row_mut(i)[j] = 0.1 + rng.f32();
        }
    }
    m
}

/// The three production objective kinds over the same feature substrate.
fn objective_instance(kind: &str, n: usize, seed: u64) -> Arc<dyn BatchedDivergence> {
    let feats = random_feats(n, 12, seed);
    match kind {
        "features" => Arc::new(FeatureBased::sqrt(feats)),
        "facility" => Arc::new(FacilityLocation::from_features(&feats)),
        "mixture" => Arc::new(Mixture::new(vec![
            (0.6, Box::new(FeatureBased::sqrt(feats.clone())) as Box<dyn BatchedDivergence>),
            (0.4, Box::new(FacilityLocation::from_features(&feats))),
        ])),
        other => panic!("unknown objective kind {other}"),
    }
}

#[test]
fn engine_equals_scalar_references_across_objectives_routes_and_cohorts() {
    check_seeded(0xE46_1E, 18, |g| {
        let kind = *g.choose(&["features", "facility", "mixture"]);
        let n = g.usize_in(30, 110);
        let k = g.usize_in(1, 18);
        let cohort = *g.choose(&[1usize, 2, 7, 64]);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let f = objective_instance(kind, n, seed);
        let all: Vec<usize> = (0..n).collect();

        // the chain the ISSUE names: batched lazy ≡ scalar lazy ≡ naive
        let scalar_lazy = lazy_greedy_reference(f.as_submodular(), &all, k);
        let naive = greedy_reference(f.as_submodular(), &all, k);
        assert_eq!(
            scalar_lazy.set, naive.set,
            "{kind}: Minoux property broken in the references themselves (n={n}, k={k})"
        );

        let mut eng =
            MaximizerEngine::new(f.as_submodular(), GainRoute::Direct).with_cohort(cohort);
        let batched = eng.lazy_greedy(&all, k);
        assert_eq!(
            batched.set, scalar_lazy.set,
            "{kind}: batched lazy diverged from scalar (n={n}, k={k}, cohort={cohort})"
        );
        assert_eq!(
            batched.value.to_bits(),
            scalar_lazy.value.to_bits(),
            "{kind}: same commits in the same order must give bit-identical value"
        );
        assert!(
            eng.stats().dispatches < scalar_lazy.oracle_calls,
            "{kind}: {} dispatches must be strictly fewer than {} scalar oracle calls",
            eng.stats().dispatches,
            scalar_lazy.oracle_calls
        );

        // naive + stochastic engine modes against their own references
        let eng_naive = eng.greedy(&all, k);
        assert_eq!(eng_naive.set, naive.set, "{kind}: batched naive diverged");
        let s_want = stochastic_greedy_reference(f.as_submodular(), &all, k, 0.2, seed);
        let s_got = eng.stochastic_greedy(&all, k, 0.2, seed);
        assert_eq!(s_got.set, s_want.set, "{kind}: batched stochastic diverged");
    });
}

#[test]
fn sharded_gain_route_bitwise_matches_direct_across_thread_counts() {
    for kind in ["features", "facility", "mixture"] {
        let f = objective_instance(kind, 500, 77);
        let all: Vec<usize> = (0..500).collect();
        let k = 25;
        let want = lazy_greedy_reference(f.as_submodular(), &all, k);
        for threads in [1usize, 2, 4] {
            let pool = Arc::new(ThreadPool::new(threads, 16));
            let metrics = Arc::new(Metrics::new());
            let backend = ShardedBackend::new(
                Arc::clone(&f),
                pool,
                Compute::Cpu,
                Arc::clone(&metrics),
            )
            .unwrap();
            let mut eng = MaximizerEngine::new(f.as_submodular(), GainRoute::Backend(&backend));
            let got = eng.lazy_greedy(&all, k);
            assert_eq!(
                got.set, want.set,
                "{kind}: sharded gain route diverged at {threads} threads"
            );
            assert_eq!(got.value.to_bits(), want.value.to_bits());
            // every engine evaluation must land on the backend's counter
            assert_eq!(
                metrics.counters.gain_evals.load(std::sync::atomic::Ordering::Relaxed),
                eng.stats().gain_evals,
                "{kind}: gain_evals metric must match engine accounting"
            );
        }
    }
}

#[test]
fn ss_then_greedy_routes_through_backend_and_matches_scalar_pipeline() {
    // the paper's headline pipeline: the engine-backed maximizer on V'
    // must reproduce the scalar lazy greedy on the same reduced set
    let f = objective_instance("features", 900, 21);
    let reference = CpuBackend::new(f.as_ref());
    let params = SsParams::default().with_seed(5);
    let (ss, sol) = ss_then_greedy(f.as_submodular(), &reference, 15, &params);
    let ss_again = sparsify(&reference, &params);
    assert_eq!(ss.kept, ss_again.kept, "sparsify must stay deterministic");
    let want = lazy_greedy_reference(f.as_submodular(), &ss.kept, 15);
    assert_eq!(sol.set, want.set, "pipeline maximizer diverged from scalar lazy greedy on V'");
    assert_eq!(sol.value.to_bits(), want.value.to_bits());
}
