//! The arena invariant, enforced at the allocator: once the round arena,
//! sampler scratch and kernel thread-locals are warm (rounds 1–2), an SS
//! round on the CPU reference backend performs **zero heap allocations**,
//! and on the sharded pool backend a small constant number (job dispatch:
//! boxed shard closures + the completion latch), independent of `n`. The
//! same invariant holds for the maximizer engine: once its arena is sized
//! (heap, version maps, cohort buffers) and the state has reserved its
//! solution vector, steady-state lazy-greedy iterations — cohort kernel,
//! heap churn, commits — allocate **exactly zero** on the CPU route. And
//! for the streaming subsystem: once a `StreamSession` has reserved
//! capacity, steady-state appends (no re-sparsify, no sieve re-grid)
//! allocate exactly zero as well.
//!
//! This file deliberately contains a single `#[test]`: the counting
//! allocator is process-global, so concurrent tests in the same binary
//! would pollute the per-round deltas.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use submodular_ss::algorithms::{
    sparsify, sparsify_candidates, sparsify_candidates_reference, sparsify_candidates_traced,
    CpuBackend, DivergenceBackend, GainRoute, MaximizerEngine, SsParams,
};
use submodular_ss::trace::Tracer;
use submodular_ss::coordinator::{Compute, Metrics, ShardedBackend};
use submodular_ss::stream::{ObjectiveSpec, StreamConfig, StreamSession};
use submodular_ss::submodular::{Concave, FeatureBased, SolState, SubmodularFn};
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Counts every allocation-path entry (alloc / alloc_zeroed / realloc);
/// frees are not interesting here.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Backend wrapper that snapshots the allocation counter at the entry of
/// every divergence batch — the deltas between consecutive snapshots are
/// exactly the allocations of one full round (prune + sample + bookkeeping
/// + the next batch's kernel). Also asserts the arena loop routes through
/// the write-into entry points only.
struct RoundProbe<'a> {
    inner: &'a dyn DivergenceBackend,
    marks: Mutex<Vec<u64>>,
}

impl<'a> RoundProbe<'a> {
    fn new(inner: &'a dyn DivergenceBackend) -> Self {
        // pre-reserve so the marks themselves never allocate mid-run
        Self { inner, marks: Mutex::new(Vec::with_capacity(64)) }
    }

    fn marks(&self) -> Vec<u64> {
        self.marks.lock().unwrap().clone()
    }
}

impl DivergenceBackend for RoundProbe<'_> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn divergences(&self, _probes: &[usize], _items: &[usize]) -> Vec<f32> {
        panic!("arena round loop must route through divergences_into");
    }

    fn divergences_into(&self, probes: &[usize], items: &[usize], out: &mut [f32]) {
        self.marks.lock().unwrap().push(ALLOCS.load(Ordering::Relaxed));
        self.inner.divergences_into(probes, items, out);
    }

    fn importance_weights(&self, _items: &[usize]) -> Vec<f64> {
        panic!("arena round loop must route through importance_weights_into");
    }

    fn importance_weights_into(&self, items: &[usize], out: &mut Vec<f64>) {
        self.inner.importance_weights_into(items, out);
    }
}

/// Objective wrapper whose states snapshot the allocation counter at every
/// batched-gain dispatch — the deltas between consecutive snapshots are
/// exactly the allocations of one engine segment (previous cohort kernel +
/// heap churn + commits + bookkeeping). Scalar `gain` panics: the engine
/// must route exclusively through `gains_into`.
struct GainProbe<'a> {
    inner: &'a FeatureBased,
    marks: Mutex<Vec<u64>>,
}

impl<'a> GainProbe<'a> {
    fn new(inner: &'a FeatureBased) -> Self {
        // pre-reserve so the marks themselves never allocate mid-run
        Self { inner, marks: Mutex::new(Vec::with_capacity(4096)) }
    }

    fn marks(&self) -> Vec<u64> {
        self.marks.lock().unwrap().clone()
    }
}

impl SubmodularFn for GainProbe<'_> {
    fn n(&self) -> usize {
        self.inner.n()
    }
    fn eval(&self, s: &[usize]) -> f64 {
        self.inner.eval(s)
    }
    fn state<'b>(&'b self) -> Box<dyn SolState + 'b> {
        Box::new(ProbeState { inner: self.inner.state(), marks: &self.marks })
    }
}

struct ProbeState<'b> {
    inner: Box<dyn SolState + 'b>,
    marks: &'b Mutex<Vec<u64>>,
}

impl SolState for ProbeState<'_> {
    fn value(&self) -> f64 {
        self.inner.value()
    }
    fn gain(&self, _v: usize) -> f64 {
        panic!("maximizer engine must route through gains_into");
    }
    fn add(&mut self, v: usize) {
        self.inner.add(v);
    }
    fn set(&self) -> &[usize] {
        self.inner.set()
    }
    fn gains_into(&self, candidates: &[usize], out: &mut [f64]) {
        self.marks.lock().unwrap().push(ALLOCS.load(Ordering::Relaxed));
        self.inner.gains_into(candidates, out);
    }
    fn reserve_additions(&mut self, additional: usize) {
        self.inner.reserve_additions(additional);
    }
}

fn feature_instance(n: usize, d: usize, seed: u64) -> FeatureBased {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.3) { rng.f32() } else { 0.0 };
        }
    }
    FeatureBased::sqrt(m)
}

#[test]
fn steady_state_rounds_allocate_zero_on_cpu_and_o_shards_on_pool() {
    // --- CPU reference backend: exactly zero ---
    let f = feature_instance(4000, 12, 3);
    let cpu = CpuBackend::new(&f);
    let params = SsParams::default().with_seed(9);
    let probe = RoundProbe::new(&cpu);
    let res = sparsify(&probe, &params);
    let marks = probe.marks();
    assert!(marks.len() >= 4, "need ≥4 rounds to observe a steady state, got {}", marks.len());
    // Everything between the entry of round 3's batch and the entry of the
    // final round's batch — ≥1 full round of kernel + prune + sample +
    // bookkeeping with a warm arena — must not touch the allocator.
    let steady = marks[marks.len() - 1] - marks[2];
    assert_eq!(
        steady, 0,
        "steady-state CPU rounds allocated {steady} times (marks: {marks:?})"
    );
    // sanity: the probed run is still the canonical result
    let want = sparsify_candidates_reference(&cpu, &(0..4000).collect::<Vec<_>>(), &params);
    assert_eq!(res.kept, want.kept);

    // --- traced SS rounds: recording is zero-alloc once the ring exists ---
    // The tracer pre-reserves its ring at enable(); after that, every
    // record_since is a mutex lock + slot overwrite. The traced run must
    // stay on the zero-alloc budget AND reproduce the untraced kept set
    // bit-for-bit (instrumentation is provably inert).
    let tracer = Tracer::disabled();
    tracer.enable("alloc-test", 4096);
    let all: Vec<usize> = (0..4000).collect();
    let probe = RoundProbe::new(&cpu);
    let traced =
        sparsify_candidates_traced(&probe, &all, &params, &mut || None, &tracer).unwrap();
    assert_eq!(traced.kept, res.kept, "tracing must not perturb the kept set");
    let marks = probe.marks();
    assert!(marks.len() >= 4, "need ≥4 traced rounds, got {}", marks.len());
    let steady = marks[marks.len() - 1] - marks[2];
    assert_eq!(
        steady, 0,
        "steady-state traced rounds allocated {steady} times (marks: {marks:?})"
    );
    assert!(!tracer.is_empty(), "the enabled tracer must have recorded round spans");
    assert_eq!(tracer.dropped(), 0, "4096 slots must hold every span of this run");

    // --- disabled tracer: the traced entry point adds zero allocations ---
    // Measured two ways: the steady-state window is zero, and the *whole*
    // disabled traced run costs exactly as many allocations as the plain
    // untraced run over the same inputs — no drift anywhere, not even in
    // setup, because a disabled tracer never builds its ring.
    let off = Tracer::disabled();
    let probe = RoundProbe::new(&cpu);
    let before = ALLOCS.load(Ordering::Relaxed);
    let quiet =
        sparsify_candidates_traced(&probe, &all, &params, &mut || None, &off).unwrap();
    let spent_off = ALLOCS.load(Ordering::Relaxed) - before;
    let marks = probe.marks();
    let steady = marks[marks.len() - 1] - marks[2];
    assert_eq!(steady, 0, "disabled tracing must stay zero-alloc (marks: {marks:?})");
    assert_eq!(quiet.kept, res.kept);
    assert!(off.is_empty(), "a disabled tracer must record nothing");
    let probe = RoundProbe::new(&cpu);
    let before = ALLOCS.load(Ordering::Relaxed);
    let plain = sparsify_candidates(&probe, &all, &params);
    let spent_plain = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(plain.kept, res.kept);
    assert_eq!(
        spent_off, spent_plain,
        "disabled tracing drifted: {spent_off} allocs traced-off vs {spent_plain} plain"
    );

    // --- sharded pool backend: bounded by job dispatch, independent of n ---
    let f2 = Arc::new(feature_instance(6000, 12, 4));
    let pool = Arc::new(ThreadPool::new(2, 16));
    let shards = 4usize;
    let sharded =
        ShardedBackend::new(f2, pool, Compute::Cpu, Arc::new(Metrics::new()))
            .unwrap()
            .with_shards(shards);
    let probe = RoundProbe::new(&sharded);
    let _ = sparsify(&probe, &SsParams::default().with_seed(11));
    let marks = probe.marks();
    assert!(marks.len() >= 4, "need ≥4 rounds, got {}", marks.len());
    let rounds_measured = (marks.len() - 3) as u64;
    let steady = marks[marks.len() - 1] - marks[2];
    let budget = rounds_measured * (12 * shards as u64 + 32);
    assert!(
        steady <= budget,
        "sharded steady-state rounds allocated {steady} > budget {budget} \
         over {rounds_measured} rounds (marks: {marks:?})"
    );

    // --- maximizer engine, CPU route: exactly zero per steady iteration ---
    // Mark 0 is the initial full-candidate fill (kernel thread-locals warm
    // up there); every delta from mark 2 to the final mark covers whole
    // engine segments — cohort kernel + heap churn + commits — with a warm
    // arena, and must not touch the allocator at all.
    let f3 = feature_instance(3000, 12, 5);
    let probe_f = GainProbe::new(&f3);
    let mut eng = MaximizerEngine::new(&probe_f, GainRoute::Direct);
    let sol = eng.lazy_greedy(&(0..3000).collect::<Vec<_>>(), 40);
    assert_eq!(sol.set.len(), 40);
    let marks = probe_f.marks();
    assert!(
        marks.len() >= 8,
        "need ≥8 gain dispatches to observe a steady state, got {}",
        marks.len()
    );
    let steady = marks[marks.len() - 1] - marks[2];
    assert_eq!(
        steady, 0,
        "steady-state maximizer iterations allocated {steady} times (marks: {marks:?})"
    );
    // the probed run must still be the canonical solution
    let want = submodular_ss::algorithms::lazy_greedy_reference(
        &f3,
        &(0..3000).collect::<Vec<_>>(),
        40,
    );
    assert_eq!(sol.set, want.set);

    // --- streaming session: steady-state appends allocate exactly zero ---
    // With capacity reserved and no re-sparsify triggered (full window),
    // an append is id assignment + row push + incremental total update +
    // atomic metric bumps — none of which may touch the allocator. The
    // measured window covers thousands of appends in both single-row and
    // batched form.
    let stream_src = feature_instance(3000, 12, 7);
    let stream_data = stream_src.feats();
    let mut sess = StreamSession::new(
        ObjectiveSpec::Features(Concave::Sqrt),
        12,
        StreamConfig::new(8),
        Arc::new(ThreadPool::new(2, 16)),
        Arc::new(Metrics::new()),
    )
    .unwrap();
    sess.reserve(3000);
    // warmup: first appends may fault in lazy one-time state
    for i in 0..200 {
        sess.append(stream_data.row(i)).unwrap();
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 200..2000 {
        sess.append(stream_data.row(i)).unwrap();
    }
    // batched form shares the same path
    sess.append(&stream_data.data()[2000 * 12..3000 * 12]).unwrap();
    let steady = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(
        steady, 0,
        "steady-state stream appends allocated {steady} times over 2800 elements"
    );
    assert_eq!(sess.live(), 3000);
    assert_eq!(sess.stats().appends, 3000);
}
