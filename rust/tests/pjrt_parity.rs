//! Integration: PJRT runtime vs the pure-Rust CPU reference.
//!
//! Requires `make artifacts` to have run (skips gracefully otherwise, so
//! `cargo test` stays green on a fresh checkout; CI runs `make test` which
//! builds artifacts first).

use submodular_ss::algorithms::{sparsify, CpuBackend, DivergenceBackend, SsParams};
use submodular_ss::runtime::{self, PjrtBackend};
use submodular_ss::submodular::{FeatureBased, SubmodularFn};
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn instance(n: usize, d: usize, seed: u64) -> FeatureBased {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.3) { rng.f32() * 2.0 } else { 0.0 };
        }
    }
    FeatureBased::sqrt(m)
}

#[test]
fn pjrt_matches_cpu_reference() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let (_svc, rt) = runtime::start_default(1).expect("start pjrt service");
    // n deliberately NOT a multiple of the tile size; d < D to test padding
    let f = instance(401, 200, 1);
    let pjrt = PjrtBackend::new(&f, rt).expect("backend");
    let cpu = CpuBackend::new(&f);

    // singleton complements agree
    let cpu_sing = cpu.singletons();
    for (v, (&a, &b)) in pjrt.singletons().iter().zip(cpu_sing).enumerate() {
        assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "sing[{v}]: pjrt {a} vs cpu {b}");
    }

    // divergences agree on irregular probe/item sets (probe count > P forces
    // multi-tile min-folding; item count > B forces block tiling)
    let mut rng = Rng::new(7);
    for trial in 0..3 {
        let probes = rng.sample_indices(401, 40 + trial * 13);
        let items: Vec<usize> =
            (0..401).filter(|v| !probes.contains(v)).collect();
        let a = pjrt.divergences(&probes, &items);
        let b = cpu.divergences(&probes, &items);
        assert_eq!(a.len(), b.len());
        for (i, (&x, &y)) in a.iter().zip(&b).enumerate() {
            assert!(
                (x - y).abs() < 1e-2 * (1.0 + y.abs()),
                "divergence[{i}] (item {v}): pjrt {x} vs cpu {y}",
                v = items[i]
            );
        }
    }
}

#[test]
fn ss_through_pjrt_prunes_like_cpu() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let (_svc, rt) = runtime::start_default(1).expect("start pjrt service");
    let f = instance(600, 128, 2);
    let pjrt = PjrtBackend::new(&f, rt).expect("backend");
    let cpu = CpuBackend::new(&f);
    let params = SsParams::default().with_seed(5);
    let a = sparsify(&pjrt, &params);
    let b = sparsify(&cpu, &params);
    // identical RNG stream; divergences agree to ~1e-3, so the pruned sets
    // can differ only at quickselect ties. Require near-identical outcomes.
    let a_set: std::collections::HashSet<_> = a.kept.iter().collect();
    let b_set: std::collections::HashSet<_> = b.kept.iter().collect();
    let inter = a_set.intersection(&b_set).count();
    let union = a_set.union(&b_set).count();
    let jaccard = inter as f64 / union as f64;
    assert!(jaccard > 0.95, "pjrt vs cpu SS sets diverge: jaccard={jaccard}");
    assert_eq!(a.rounds, b.rounds);
}

#[test]
fn utility_artifact_matches_eval() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let (_svc, rt) = runtime::start_default(1).expect("start pjrt service");
    let f = instance(50, 64, 3);
    let set: Vec<usize> = vec![1, 5, 9, 33];
    let on_device = rt.utility(f.feats(), &set).expect("utility");
    let on_cpu = f.eval(&set);
    assert!((on_device - on_cpu).abs() < 1e-3 * (1.0 + on_cpu.abs()));
}

#[test]
fn accelerated_greedy_matches_cpu_greedy() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts/ not built");
        return;
    }
    let (_svc, rt) = runtime::start_default(1).expect("start pjrt service");
    let f = instance(300, 128, 9);
    let all: Vec<usize> = (0..300).collect();
    let cpu = submodular_ss::algorithms::greedy(&f, &all, 12);
    let dev = submodular_ss::algorithms::accelerated_greedy(&f, &rt, &all, 12).expect("accel");
    // f32 gain batches can flip near-tie argmaxes; values must agree tightly
    assert!(
        (dev.value - cpu.value).abs() < 1e-3 * (1.0 + cpu.value),
        "accelerated {} vs cpu {}",
        dev.value,
        cpu.value
    );
    assert_eq!(dev.set.len(), cpu.set.len());
}
