//! Reproduces paper Figure 5: SS relative utility against (n, |V'|) per day.

use submodular_ss::bench::full_scale;
use submodular_ss::eval::news;

fn main() {
    let (days, hi) = if full_scale() { (200, 8000) } else { (15, 2000) };
    let records = news::run_days(days, 300, hi, 5);
    let t = news::fig5(&records);
    t.print();
    t.save("fig5.json");
}
