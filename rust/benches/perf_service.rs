//! Perf: summarization-service throughput/latency under a request burst —
//! the L3 serving numbers for EXPERIMENTS.md §Perf.

use submodular_ss::algorithms::SsParams;
use submodular_ss::bench::full_scale;
use submodular_ss::coordinator::{ServiceConfig, SummarizationService, SummarizeRequest};
use submodular_ss::data::{CorpusParams, NewsGenerator};
use submodular_ss::util::stats::{Samples, Timer};

fn main() {
    let (requests, n) = if full_scale() { (40, 2000) } else { (12, 600) };
    let generator = NewsGenerator::new(CorpusParams::default(), 3);
    let days: Vec<_> = (0..requests).map(|i| generator.day(n, 0, 100 + i as u64)).collect();

    for workers in [1usize, 2, 4] {
        let svc = SummarizationService::start(
            ServiceConfig { workers, queue_depth: 64, compute_threads: 2 },
            None,
        );
        let wall = Timer::new();
        let tickets: Vec<_> = days
            .iter()
            .enumerate()
            .map(|(i, d)| {
                svc.submit(SummarizeRequest::features(
                    d.feats.clone(),
                    d.k,
                    SsParams::default().with_seed(i as u64),
                ))
            })
            .collect();
        let mut lat = Samples::new();
        for t in tickets {
            lat.push(t.wait().unwrap().latency_s);
        }
        let total = wall.elapsed_s();
        println!(
            "workers={workers}: {:.2} req/s | latency p50 {:.3}s p95 {:.3}s (n={n}, {requests} reqs)",
            requests as f64 / total,
            lat.percentile(50.0),
            lat.percentile(95.0)
        );
    }
}
