//! Reproduces paper Figure 1: utility f(S) and time vs data size n for lazy
//! greedy, sieve-streaming (50k memory) and SS+lazy-greedy.
//! CI scale by default; SS_FULL=1 runs the paper's n ∈ [2000, 20000].

use submodular_ss::bench::full_scale;
use submodular_ss::eval::news;

fn main() {
    let sizes: Vec<usize> = if full_scale() {
        vec![2000, 4000, 8000, 12000, 16000, 20000]
    } else {
        vec![500, 1000, 2000, 4000]
    };
    let t = news::fig1(&sizes, 1);
    t.print();
    t.save("fig1.json");
}
