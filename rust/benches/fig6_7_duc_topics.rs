//! Reproduces paper Figures 6 and 7: DUC-like topic-set summarization stats
//! against 400-word (Fig 6) and 200-word (Fig 7) references (paper: 60 sets).

use submodular_ss::bench::full_scale;
use submodular_ss::eval::duc;

fn main() {
    let (sets, n) = if full_scale() { (60, 800) } else { (8, 250) };
    let f6 = duc::fig67(sets, n, 400, 6);
    f6.print();
    f6.save("fig6.json");
    let f7 = duc::fig67(sets, n, 200, 6);
    f7.print();
    f7.save("fig7.json");
}
