//! Perf: distributed fan-out — the coordinator/worker cluster vs a
//! single-process run of the same two-round pipeline.
//!
//! For each ground-set size the bench runs (a) a single-process
//! baseline — SS over the full set, then lazy greedy — and (b) the
//! cluster at 1, 2 and 4 loopback workers (full wire protocol, real
//! worker runtimes, one thread each). Two gates:
//!
//! * **relative utility ≥ 0.95, always on** — shard-pruned-then-merged
//!   summaries must stay within 5% of the single-process objective
//!   value at every worker count (the paper's two-round quality claim,
//!   §1.2, measured at bench scale);
//! * **≥ 2× wall-clock at 4 workers vs 1, `SS_STRICT=1` only** — the
//!   scaling claim, opt-in because it depends on the host actually
//!   having spare cores.
//!
//! Machine-readable `BENCH_cluster.json` lands at the repository root.
//!
//! Run: `cargo bench --bench perf_cluster` (SS_FULL=1 for paper scale,
//! SS_SMOKE=1 for the CI smoke, SS_STRICT=1 to enforce the wall gate).

use std::thread;

use submodular_ss::algorithms::{lazy_greedy, sparsify, CpuBackend, SsParams};
use submodular_ss::bench::Table;
use submodular_ss::cluster::{
    ClusterConfig, ClusterCoordinator, ClusterResponse, WorkerConfig, WorkerRuntime,
};
use submodular_ss::coordinator::ServiceConfig;
use submodular_ss::net::{loopback_pair, Transport};
use submodular_ss::submodular::{Concave, FeatureBased, ObjectiveSpec};
use submodular_ss::util::json::Json;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::stats::Timer;
use submodular_ss::util::vecmath::FeatureMatrix;

fn clustered_rows(n: usize, clusters: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f32>> = (0..clusters)
        .map(|_| (0..d).map(|_| if rng.bool(0.4) { rng.f32() * 3.0 } else { 0.0 }).collect())
        .collect();
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        let c = &centers[rng.below(clusters)];
        for j in 0..d {
            m.row_mut(i)[j] = (c[j] + 0.05 * rng.f32()).max(0.0);
        }
    }
    m
}

/// One cluster run: `workers` loopback worker runtimes, summarize once,
/// clean shutdown. Returns the response (which carries its own wall).
fn run_cluster(
    workers: usize,
    rows: &FeatureMatrix,
    k: usize,
    params: &SsParams,
    seed: u64,
) -> ClusterResponse {
    let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(workers);
    let mut threads = Vec::with_capacity(workers);
    for id in 0..workers {
        let (coord_end, worker_end, _kill) = loopback_pair();
        transports.push(Box::new(coord_end));
        threads.push(thread::spawn(move || {
            let config = WorkerConfig {
                worker_id: id as u64,
                service: ServiceConfig { workers: 2, compute_threads: 2, ..Default::default() },
            };
            WorkerRuntime::new(config).serve(Box::new(worker_end))
        }));
    }
    let cfg = ClusterConfig { shards: 8, seed, ..Default::default() };
    let coordinator = ClusterCoordinator::connect(transports, cfg).expect("cluster connect");
    let resp = coordinator
        .summarize(ObjectiveSpec::Features(Concave::Sqrt), rows, k, params)
        .expect("cluster summarize");
    drop(coordinator); // shutdown flows to every worker
    for t in threads {
        t.join().unwrap().expect("worker wire error");
    }
    resp
}

fn main() {
    let smoke = std::env::var("SS_SMOKE").map(|v| v == "1").unwrap_or(false);
    let strict = std::env::var("SS_STRICT").map(|v| v == "1").unwrap_or(false);
    let sizes: Vec<usize> = if smoke { vec![1_500, 4_000] } else { vec![20_000, 80_000] };
    let d = 16;
    let k = 16;
    let seed = 13u64;
    let params = SsParams::default().with_seed(seed);
    let worker_counts = [1usize, 2, 4];

    let mut table = Table::new(
        "Distributed SS: loopback cluster vs single process (Features/sqrt, shards=8)",
        &["n", "topology", "wall_s", "speedup", "f(S)", "rel_utility", "|union|", "retries"],
    );
    let mut entries = Vec::new();

    for &n in &sizes {
        let rows = clustered_rows(n, 25, d, seed);

        // single-process baseline: SS over the full ground set + greedy
        let f = FeatureBased::new(rows.clone(), Concave::Sqrt);
        let t = Timer::new();
        let backend = CpuBackend::new(&f);
        let ss = sparsify(&backend, &params);
        let s = lazy_greedy(&f, &ss.kept, k);
        let base_wall = t.elapsed_s();
        table.row(vec![
            n.to_string(),
            "1 process".into(),
            format!("{base_wall:.3}"),
            "-".into(),
            format!("{:.3}", s.value),
            "1.000".into(),
            ss.kept.len().to_string(),
            "-".into(),
        ]);

        let mut wall_1w = 0.0f64;
        for &w in &worker_counts {
            let resp = run_cluster(w, &rows, k, &params, seed);
            if w == 1 {
                wall_1w = resp.wall_s;
            }
            let rel = resp.value / s.value;
            let speedup = wall_1w / resp.wall_s;
            table.row(vec![
                n.to_string(),
                format!("{w} worker{}", if w == 1 { "" } else { "s" }),
                format!("{:.3}", resp.wall_s),
                format!("{speedup:.2}x"),
                format!("{:.3}", resp.value),
                format!("{rel:.3}"),
                resp.union.to_string(),
                resp.retries.to_string(),
            ]);

            // quality gate is unconditional: the two-round merge must not
            // cost more than 5% of the single-process objective value
            assert!(
                rel >= 0.95,
                "n={n} workers={w}: relative utility {rel:.3} below the 0.95 gate"
            );
            if strict && w == 4 {
                assert!(
                    speedup >= 2.0,
                    "n={n}: 4-worker speedup {speedup:.2}x below the strict 2x gate"
                );
            }

            entries.push(Json::obj(vec![
                ("n", Json::Num(n as f64)),
                ("workers", Json::Num(w as f64)),
                ("wall_s", Json::Num(resp.wall_s)),
                ("speedup_vs_1_worker", Json::Num(speedup)),
                ("value", Json::Num(resp.value)),
                ("rel_utility", Json::Num(rel)),
                ("union", Json::Num(resp.union as f64)),
                ("final_reduced", Json::Num(resp.final_reduced as f64)),
                ("shard_rounds", Json::Num(resp.shard_rounds as f64)),
                ("retries", Json::Num(resp.retries as f64)),
                ("baseline_wall_s", Json::Num(base_wall)),
            ]));
        }
    }
    table.print();

    let report = Json::obj(vec![
        ("bench", Json::Str("perf_cluster".to_string())),
        ("smoke", Json::Num(if smoke { 1.0 } else { 0.0 })),
        ("strict", Json::Num(if strict { 1.0 } else { 0.0 })),
        ("shards", Json::Num(8.0)),
        ("k", Json::Num(k as f64)),
        ("d", Json::Num(d as f64)),
        ("runs", Json::Arr(entries)),
    ]);
    let out = format!("{}/../BENCH_cluster.json", env!("CARGO_MANIFEST_DIR"));
    std::fs::write(&out, report.pretty()).expect("write BENCH_cluster.json");
    println!("(saved to {out})");
}
