//! Perf: the facility-location divergence batch — cache-blocked row-walk
//! kernel vs the scalar `pair_gain` fallback every objective gets for
//! free. The §Perf facility-location numbers in EXPERIMENTS.md come from
//! this target.
//!
//! The scalar fallback walks two stride-`n` similarity *columns* per
//! `(probe, item)` pair — a cache miss per ground element. The blocked
//! kernel streams similarity *rows* contiguously against an L2-resident
//! accumulator tile. Same math, same bits, very different memory traffic;
//! the acceptance bar is ≥ 2× at n ≥ 2000 (measured much higher).
//!
//! Run: `cargo bench --bench perf_facility_divergence` (SS_FULL=1 for the
//! paper-scale shape). Prints a ready-to-paste EXPERIMENTS.md table row.

use submodular_ss::bench::{bench, full_scale};
use submodular_ss::submodular::{BatchedDivergence, FacilityLocation, SolState, SubmodularFn};
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

/// Wrapper that hides `FacilityLocation`'s kernel overrides so the default
/// scalar `BatchedDivergence` path can be timed on the same instance.
struct ScalarFallback<'a>(&'a FacilityLocation);

impl SubmodularFn for ScalarFallback<'_> {
    fn n(&self) -> usize {
        self.0.n()
    }
    fn eval(&self, s: &[usize]) -> f64 {
        self.0.eval(s)
    }
    fn state<'b>(&'b self) -> Box<dyn SolState + 'b> {
        self.0.state()
    }
    fn pair_gain(&self, u: usize, v: usize) -> f64 {
        self.0.pair_gain(u, v)
    }
    fn singleton(&self, v: usize) -> f64 {
        self.0.singleton(v)
    }
    fn singleton_complements(&self) -> Vec<f64> {
        self.0.singleton_complements()
    }
}

impl BatchedDivergence for ScalarFallback<'_> {
    fn as_submodular(&self) -> &dyn SubmodularFn {
        self
    }
    // divergences_batch / pair_gains_batch: trait defaults = scalar loop
}

fn instance(n: usize, d: usize, seed: u64) -> FacilityLocation {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.3) { rng.f32() } else { 0.0 };
        }
    }
    FacilityLocation::from_features(&m)
}

fn main() {
    let n = if full_scale() { 4000 } else { 2000 };
    // probes per SS round: r·log₂ n with the paper's r = 8
    let p = (8.0 * (n as f64).log2()).ceil() as usize;
    let f = instance(n, 64, 1);
    let probes: Vec<usize> = (0..p).collect();
    let items: Vec<usize> = (p..n).collect();
    let sing = f.singleton_complements();
    let probe_sing: Vec<f64> = probes.iter().map(|&u| sing[u]).collect();
    let pairs = (probes.len() * items.len()) as f64;
    println!("facility-location divergence batch: n={n}, probes={p}, items={}", items.len());

    let scalar = ScalarFallback(&f);
    let r_scalar = bench("fl_scalar_pair_gain_fallback", 0, 2, || {
        scalar.divergences_batch(&probes, &probe_sing, &items)
    });
    let r_blocked = bench("fl_blocked_row_walk_kernel", 1, 5, || {
        f.divergences_batch(&probes, &probe_sing, &items)
    });

    // same bits, not just close
    let a = scalar.divergences_batch(&probes, &probe_sing, &items);
    let b = f.divergences_batch(&probes, &probe_sing, &items);
    assert_eq!(a, b, "blocked kernel must be bit-identical to the scalar fallback");

    let speedup = r_scalar.median_s / r_blocked.median_s;
    println!(
        "throughput: scalar {:.2} | blocked {:.2} Mpair/s | speedup {speedup:.1}x",
        pairs / r_scalar.median_s / 1e6,
        pairs / r_blocked.median_s / 1e6,
    );
    println!(
        "EXPERIMENTS.md row: | {n} | {p} | {:.3} | {:.3} | {:.1}x |",
        r_scalar.median_s, r_blocked.median_s, speedup
    );
    if n >= 2000 {
        assert!(
            speedup >= 2.0,
            "blocked facility-location kernel must be ≥ 2x the scalar fallback at n ≥ 2000 \
             (measured {speedup:.2}x)"
        );
    }
}
