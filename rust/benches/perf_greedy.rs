//! Perf micro-bench: greedy variants (naive, lazy, stochastic) + the full
//! SS pipeline — oracle-call accounting and wall-clock.

use submodular_ss::algorithms::{
    greedy, lazy_greedy, sparsify, ss_then_greedy, stochastic_greedy, CpuBackend, SsParams,
};
use submodular_ss::bench::{bench, full_scale};
use submodular_ss::submodular::FeatureBased;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

fn main() {
    let (n, d, k) = if full_scale() { (8000, 128, 40) } else { (2500, 64, 25) };
    let mut rng = Rng::new(2);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.3) { rng.f32() } else { 0.0 };
        }
    }
    let f = FeatureBased::sqrt(m);
    let all: Vec<usize> = (0..n).collect();
    let iters = 3;

    bench("naive_greedy", 0, 1, || greedy(&f, &all, k));
    bench("lazy_greedy", 1, iters, || lazy_greedy(&f, &all, k));
    bench("stochastic_greedy_eps0.1", 1, iters, || stochastic_greedy(&f, &all, k, 0.1, 7));
    let backend = CpuBackend::new(&f);
    bench("ss_sparsify_only", 1, iters, || sparsify(&backend, &SsParams::default()));
    bench("ss_plus_lazy_greedy", 1, iters, || ss_then_greedy(&f, &backend, k, &SsParams::default()));

    // oracle-call accounting (single runs)
    let g = greedy(&f, &all, k);
    let lz = lazy_greedy(&f, &all, k);
    let (ss, sol) = ss_then_greedy(&f, &backend, k, &SsParams::default());
    println!(
        "oracle calls: naive {} | lazy {} | ss {} divergence evals + {} gains (|V'|={})",
        g.oracle_calls, lz.oracle_calls, ss.divergence_evals, sol.oracle_calls, ss.kept.len()
    );
}
