//! Perf: the **batched maximizer engine** vs the frozen scalar greedy
//! family — oracle-dispatch accounting and wall-clock, per objective and
//! gain route. The baseline legs are the pre-refactor scalar loops,
//! compiled in as `lazy_greedy_reference` / `greedy_reference` /
//! `stochastic_greedy_reference`; the engine legs run the same algorithms
//! with cohort-batched `gains_into` kernels, inline (`Direct`) and fanned
//! over the worker pool (`Backend` on `ShardedBackend`).
//!
//! Mirrors `perf_ss_round`: bit-identity between every engine leg and its
//! scalar reference is asserted before timing; prints ready-to-paste
//! EXPERIMENTS.md rows and emits machine-readable `BENCH_greedy.json` at
//! the repository root.
//!
//! What is asserted, and why (EXPERIMENTS.md §Perf has the measurement):
//! the feature-based gain loop is accumulation-bound, so the batched
//! kernel's `g(cov)` caching is worth only ~1.0–1.05× single-core — the
//! durable win is the **dispatch collapse** (tens of thousands of scalar
//! oracle calls → hundreds of kernel calls), which lets the pool route
//! fan the big sweeps out and the PJRT route batch whole cohorts per
//! executor call; facility location's row-walk is a real single-core
//! multiple once the similarity matrix exceeds cache. Shared CI runners
//! are noisy, so the default assert is a **no-regression gate** (best
//! engine route ≥ 0.9× scalar at n ≥ 20 000, on bit-identical outputs);
//! `SS_STRICT=1` opts into the ≥ 1.3× multi-core target for runs on real
//! hardware.
//!
//! Run: `cargo bench --bench perf_greedy` (SS_FULL=1 for paper scale,
//! SS_SMOKE=1 for the CI smoke that stays below the gate threshold).

use std::sync::Arc;

use submodular_ss::algorithms::{
    greedy_reference, lazy_greedy_reference, sparsify, ss_then_greedy,
    stochastic_greedy_reference, GainRoute, MaximizerEngine, SsParams,
};
use submodular_ss::bench::{bench, full_scale, Table};
use submodular_ss::coordinator::{Compute, Metrics, ShardedBackend};
use submodular_ss::submodular::{BatchedDivergence, FacilityLocation, FeatureBased};
use submodular_ss::util::json::Json;
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.3) { rng.f32() } else { 0.0 };
        }
    }
    m
}

fn main() {
    let smoke = std::env::var("SS_SMOKE").map(|v| v == "1").unwrap_or(false);
    // feature-based carries the acceptance gate; facility location is
    // capped by its O(n²) similarity matrix and reported for tracking
    let (n_feat, k_feat) = if full_scale() {
        (50_000, 100)
    } else if smoke {
        (4_000, 25)
    } else {
        (20_000, 50)
    };
    let (n_fl, k_fl) = if smoke { (1_000, 15) } else { (3_000, 30) };
    let d = 16;
    let iters = if smoke { 1 } else { 3 };

    let pool = Arc::new(ThreadPool::default_for_host());
    let shards = pool.threads() * 2;
    let mut table = Table::new(
        "Greedy family: scalar references vs batched engine",
        &["case", "n", "k", "scalar_s", "engine_s", "speedup", "scalar_calls", "engine_evals", "dispatches"],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    let mut feat_speedup = 0.0f64;

    // ---------- feature-based: lazy greedy (the headline + gate) ----------
    {
        // one shared instance: every leg (scalar, Direct, Backend) runs the
        // same objective, so the bit-identity asserts compare routes only
        let fb: Arc<dyn BatchedDivergence> = Arc::new(FeatureBased::sqrt(feats(n_feat, d, 1)));
        let f = fb.as_submodular();
        let all: Vec<usize> = (0..n_feat).collect();
        let backend = ShardedBackend::new(
            Arc::clone(&fb),
            Arc::clone(&pool),
            Compute::Cpu,
            Arc::new(Metrics::new()),
        )
        .unwrap()
        .with_shards(shards);

        // bit-identity first: every engine leg must equal the scalar oracle
        let want = lazy_greedy_reference(f, &all, k_feat);
        let mut eng_direct = MaximizerEngine::new(f, GainRoute::Direct);
        let got = eng_direct.lazy_greedy(&all, k_feat);
        assert_eq!(got.set, want.set, "engine(Direct) must be bit-identical to scalar lazy");
        let mut eng_pool = MaximizerEngine::new(fb.as_submodular(), GainRoute::Backend(&backend));
        let got_pool = eng_pool.lazy_greedy(&all, k_feat);
        assert_eq!(got_pool.set, want.set, "engine(Backend) must be bit-identical to scalar lazy");
        assert!(
            eng_direct.stats().dispatches < want.oracle_calls,
            "engine dispatches {} must be strictly fewer than scalar oracle calls {}",
            eng_direct.stats().dispatches,
            want.oracle_calls
        );

        let r_scalar = bench("lazy_greedy_scalar_features", 1, iters, || {
            lazy_greedy_reference(f, &all, k_feat)
        });
        let r_direct =
            bench("lazy_greedy_engine_direct", 1, iters, || eng_direct.lazy_greedy(&all, k_feat));
        let r_pool =
            bench("lazy_greedy_engine_pool", 1, iters, || eng_pool.lazy_greedy(&all, k_feat));
        let speedup_direct = r_scalar.median_s / r_direct.median_s;
        let speedup_pool = r_scalar.median_s / r_pool.median_s;
        feat_speedup = speedup_direct.max(speedup_pool);
        for (case, r, speedup, stats) in [
            ("lazy/features/direct", &r_direct, speedup_direct, eng_direct.stats()),
            ("lazy/features/pool", &r_pool, speedup_pool, eng_pool.stats()),
        ] {
            table.row(vec![
                case.into(),
                n_feat.to_string(),
                k_feat.to_string(),
                format!("{:.4}", r_scalar.median_s),
                format!("{:.4}", r.median_s),
                format!("{speedup:.2}x"),
                want.oracle_calls.to_string(),
                stats.gain_evals.to_string(),
                stats.dispatches.to_string(),
            ]);
            json_rows.push(Json::obj(vec![
                ("case", Json::Str(case.to_string())),
                ("n", Json::Num(n_feat as f64)),
                ("k", Json::Num(k_feat as f64)),
                ("scalar_median_s", Json::Num(r_scalar.median_s)),
                ("engine_median_s", Json::Num(r.median_s)),
                ("speedup", Json::Num(speedup)),
                ("scalar_oracle_calls", Json::Num(want.oracle_calls as f64)),
                ("engine_gain_evals", Json::Num(stats.gain_evals as f64)),
                ("engine_dispatches", Json::Num(stats.dispatches as f64)),
            ]));
        }

        // stochastic greedy rides the same kernels — report for tracking
        let s_want = stochastic_greedy_reference(f, &all, k_feat, 0.1, 7);
        let s_got = eng_direct.stochastic_greedy(&all, k_feat, 0.1, 7);
        assert_eq!(s_got.set, s_want.set, "engine stochastic must match scalar");
        let r_s_scalar = bench("stochastic_scalar_features", 1, iters, || {
            stochastic_greedy_reference(f, &all, k_feat, 0.1, 7)
        });
        let r_s_eng = bench("stochastic_engine_features", 1, iters, || {
            eng_direct.stochastic_greedy(&all, k_feat, 0.1, 7)
        });
        let sp = r_s_scalar.median_s / r_s_eng.median_s;
        table.row(vec![
            "stochastic/features".into(),
            n_feat.to_string(),
            k_feat.to_string(),
            format!("{:.4}", r_s_scalar.median_s),
            format!("{:.4}", r_s_eng.median_s),
            format!("{sp:.2}x"),
            s_want.oracle_calls.to_string(),
            s_got.oracle_calls.to_string(),
            eng_direct.stats().dispatches.to_string(),
        ]);
        json_rows.push(Json::obj(vec![
            ("case", Json::Str("stochastic/features".to_string())),
            ("n", Json::Num(n_feat as f64)),
            ("k", Json::Num(k_feat as f64)),
            ("scalar_median_s", Json::Num(r_s_scalar.median_s)),
            ("engine_median_s", Json::Num(r_s_eng.median_s)),
            ("speedup", Json::Num(sp)),
        ]));

        // full pipeline seam (the old ss_plus_lazy_greedy leg): arena
        // sparsify handing V' to the maximizer over the same sharded
        // backend — catches regressions from pool contention between the
        // round loop and the gain fan-out that the isolated legs can't see
        let params = SsParams::default().with_seed(7);
        let (ss_ref, sol_eng) = ss_then_greedy(f, &backend, k_feat, &params);
        let want_pipe = lazy_greedy_reference(f, &ss_ref.kept, k_feat);
        assert_eq!(
            sol_eng.set, want_pipe.set,
            "pipeline maximizer must match scalar lazy greedy on V'"
        );
        let r_pipe_scalar = bench("ss_plus_lazy_scalar", 1, iters, || {
            let ss = sparsify(&backend, &params);
            lazy_greedy_reference(f, &ss.kept, k_feat)
        });
        let r_pipe_eng = bench("ss_plus_lazy_engine", 1, iters, || {
            ss_then_greedy(f, &backend, k_feat, &params)
        });
        let sp = r_pipe_scalar.median_s / r_pipe_eng.median_s;
        table.row(vec![
            "pipeline/features".into(),
            n_feat.to_string(),
            k_feat.to_string(),
            format!("{:.4}", r_pipe_scalar.median_s),
            format!("{:.4}", r_pipe_eng.median_s),
            format!("{sp:.2}x"),
            want_pipe.oracle_calls.to_string(),
            sol_eng.oracle_calls.to_string(),
            "-".into(),
        ]);
        json_rows.push(Json::obj(vec![
            ("case", Json::Str("pipeline/features".to_string())),
            ("n", Json::Num(n_feat as f64)),
            ("k", Json::Num(k_feat as f64)),
            ("reduced", Json::Num(ss_ref.kept.len() as f64)),
            ("scalar_median_s", Json::Num(r_pipe_scalar.median_s)),
            ("engine_median_s", Json::Num(r_pipe_eng.median_s)),
            ("speedup", Json::Num(sp)),
        ]));
    }

    // ---------- facility location: naive greedy (column-walk → row-walk) ----------
    {
        let fl = FacilityLocation::from_features(&feats(n_fl, d, 2));
        let all: Vec<usize> = (0..n_fl).collect();
        let want = greedy_reference(&fl, &all, k_fl);
        let mut eng = MaximizerEngine::new(&fl, GainRoute::Direct);
        let got = eng.greedy(&all, k_fl);
        assert_eq!(got.set, want.set, "engine naive greedy must match scalar on facility");
        let r_scalar =
            bench("naive_greedy_scalar_facility", 1, iters, || greedy_reference(&fl, &all, k_fl));
        let r_eng = bench("naive_greedy_engine_facility", 1, iters, || eng.greedy(&all, k_fl));
        let sp = r_scalar.median_s / r_eng.median_s;
        table.row(vec![
            "naive/facility".into(),
            n_fl.to_string(),
            k_fl.to_string(),
            format!("{:.4}", r_scalar.median_s),
            format!("{:.4}", r_eng.median_s),
            format!("{sp:.2}x"),
            want.oracle_calls.to_string(),
            got.oracle_calls.to_string(),
            eng.stats().dispatches.to_string(),
        ]);
        json_rows.push(Json::obj(vec![
            ("case", Json::Str("naive/facility".to_string())),
            ("n", Json::Num(n_fl as f64)),
            ("k", Json::Num(k_fl as f64)),
            ("scalar_median_s", Json::Num(r_scalar.median_s)),
            ("engine_median_s", Json::Num(r_eng.median_s)),
            ("speedup", Json::Num(sp)),
        ]));
    }

    table.print();
    let report = Json::obj(vec![
        ("bench", Json::Str("perf_greedy".to_string())),
        ("threads", Json::Num(pool.threads() as f64)),
        ("shards", Json::Num(shards as f64)),
        ("smoke", Json::Num(if smoke { 1.0 } else { 0.0 })),
        ("full_scale", Json::Num(if full_scale() { 1.0 } else { 0.0 })),
        ("rows", Json::Arr(json_rows)),
    ]);
    // repo root (one level above the crate), alongside BENCH_ss_round.json
    let out = format!("{}/../BENCH_greedy.json", env!("CARGO_MANIFEST_DIR"));
    std::fs::write(&out, report.pretty()).expect("write BENCH_greedy.json");
    println!("(saved to {out})");

    if n_feat >= 20_000 {
        assert!(
            feat_speedup >= 0.9,
            "batched engine regressed below the scalar lazy-greedy baseline at n ≥ 20000 \
             (best route measured {feat_speedup:.2}x; the engine must never be slower beyond noise)"
        );
        if std::env::var("SS_STRICT").map(|v| v == "1").unwrap_or(false) {
            assert!(
                feat_speedup >= 1.3,
                "SS_STRICT target not met: {feat_speedup:.2}x < 1.3x (expected on multi-core \
                 hardware where the init sweep shards; see EXPERIMENTS.md)"
            );
        }
    }
}
