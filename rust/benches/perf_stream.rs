//! Perf: the **streaming ingestion subsystem** vs the only alternative the
//! repo had before it — ad-hoc batch re-runs (rebuild the objective over
//! the full prefix and run `ss_then_greedy` from scratch at every summary
//! point). The stream leg drives a `StreamSession` (windowed
//! re-sparsification + intermediate stochastic snapshots + one final exact
//! snapshot); the baseline leg re-runs the batch pipeline on the growing
//! prefix at the same summary points. Work compared: same arrival order,
//! same k, same SS parameters, one summary per "day" plus a final one.
//!
//! Reported: append throughput (elements/s through the session, inline
//! re-sparsifications included), attributed per-re-sparsify latency, both
//! legs' totals, and final-summary relative utility (stream vs batch
//! oracle at matched k — the quality cost of windowed eviction).
//! Machine-readable `BENCH_stream.json` lands at the repository root.
//!
//! Asserts (skipped under SS_SMOKE=1, CI's release-smoke leg):
//! * no-regression gate: stream total ≥ 0.9× the batch-rerun total
//!   (streaming exists to beat prefix re-runs; it must at minimum never
//!   lose to them beyond noise),
//! * quality: final stream summary ≥ 0.85× the batch oracle's value on
//!   redundancy-heavy data.
//!
//! Run: `cargo bench --bench perf_stream` (SS_FULL=1 for paper scale,
//! SS_SMOKE=1 for the CI smoke).

use std::sync::Arc;

use submodular_ss::algorithms::{ss_then_greedy, SsParams};
use submodular_ss::bench::{full_scale, Table};
use submodular_ss::coordinator::{Compute, Metrics, ShardedBackend};
use submodular_ss::stream::{ObjectiveSpec, SnapshotMode, StreamConfig, StreamSession};
use submodular_ss::submodular::{BatchedDivergence, Concave, FeatureBased};
use submodular_ss::util::json::Json;
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::stats::Timer;
use submodular_ss::util::vecmath::FeatureMatrix;

/// Redundancy-heavy stream (clustered rows): SS's natural habitat, and the
/// regime where windowed eviction is supposed to be near-lossless.
fn clustered_rows(n: usize, clusters: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f32>> = (0..clusters)
        .map(|_| (0..d).map(|_| if rng.bool(0.4) { rng.f32() * 3.0 } else { 0.0 }).collect())
        .collect();
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        let c = &centers[rng.below(clusters)];
        for j in 0..d {
            m.row_mut(i)[j] = (c[j] + 0.05 * rng.f32()).max(0.0);
        }
    }
    m
}

fn main() {
    let smoke = std::env::var("SS_SMOKE").map(|v| v == "1").unwrap_or(false);
    let (days, per_day) = if full_scale() {
        (12usize, 8_000usize)
    } else if smoke {
        (5, 800)
    } else {
        (10, 4_000)
    };
    let d = 16;
    let k = 10;
    let n_total = days * per_day;
    let seed = 7u64;
    let params = SsParams::default().with_seed(seed);
    let high_water = (2 * per_day / 3).max(64);

    let data = clustered_rows(n_total, 25, d, seed);
    let pool = Arc::new(ThreadPool::default_for_host());

    // --- baseline: batch re-run over the growing prefix at every day ---
    let base_timer = Timer::new();
    let mut batch_final_value = 0.0f64;
    for day in 1..=days {
        let prefix = day * per_day;
        let f: Arc<dyn BatchedDivergence> =
            Arc::new(FeatureBased::sqrt(data.gather(&(0..prefix).collect::<Vec<_>>())));
        let backend = ShardedBackend::new(
            Arc::clone(&f),
            Arc::clone(&pool),
            Compute::Cpu,
            Arc::new(Metrics::new()),
        )
        .unwrap();
        let (_ss, sol) = ss_then_greedy(f.as_submodular(), &backend, k, &params);
        batch_final_value = sol.value;
    }
    let baseline_s = base_timer.elapsed_s();

    // --- stream: one session, windowed re-sparsify, daily snapshots ---
    let stream_timer = Timer::new();
    let mut sess = StreamSession::new(
        ObjectiveSpec::Features(Concave::Sqrt),
        d,
        StreamConfig::new(k).with_ss(params.clone()).with_high_water(high_water),
        Arc::clone(&pool),
        Arc::new(Metrics::new()),
    )
    .unwrap();
    sess.reserve(n_total);
    let mut append_s = 0.0f64;
    let mut resparsify_total_s = 0.0f64;
    let mut windows = 0usize;
    let mut snapshot_s = 0.0f64;
    for day in 0..days {
        let t = Timer::new();
        let r = sess
            .append(&data.data()[day * per_day * d..(day + 1) * per_day * d])
            .unwrap();
        append_s += t.elapsed_s();
        // the session times its own re-sparsifications (SS pass +
        // compaction only), so the latency row is not polluted by the
        // day's per-element append/filter work
        resparsify_total_s += r.resparsify_s;
        windows += r.resparsifies;
        let t = Timer::new();
        let snap = sess.snapshot_summary(SnapshotMode::Intermediate).unwrap();
        snapshot_s += t.elapsed_s();
        assert_eq!(snap.summary.len(), k.min(sess.live()));
    }
    let t = Timer::new();
    let final_snap = sess.snapshot_summary(SnapshotMode::Final).unwrap();
    snapshot_s += t.elapsed_s();
    let stream_s = stream_timer.elapsed_s();
    let stats = sess.close();

    let speedup = baseline_s / stream_s;
    let rel_utility = final_snap.value / batch_final_value;
    let append_throughput = n_total as f64 / append_s;
    let resparsify_latency_s =
        if windows > 0 { resparsify_total_s / windows as f64 } else { 0.0 };

    let mut table = Table::new(
        "Streaming session vs ad-hoc batch re-runs (one summary per day)",
        &[
            "n_total", "days", "hw", "batch_s", "stream_s", "speedup", "appends/s",
            "resparsify_s", "windows", "live_end", "rel_utility",
        ],
    );
    table.row(vec![
        n_total.to_string(),
        days.to_string(),
        high_water.to_string(),
        format!("{baseline_s:.3}"),
        format!("{stream_s:.3}"),
        format!("{speedup:.2}x"),
        format!("{append_throughput:.0}"),
        format!("{resparsify_latency_s:.4}"),
        windows.to_string(),
        stats.live.to_string(),
        format!("{rel_utility:.4}"),
    ]);
    table.print();

    let report = Json::obj(vec![
        ("bench", Json::Str("perf_stream".to_string())),
        ("threads", Json::Num(pool.threads() as f64)),
        ("smoke", Json::Num(if smoke { 1.0 } else { 0.0 })),
        ("full_scale", Json::Num(if full_scale() { 1.0 } else { 0.0 })),
        ("n_total", Json::Num(n_total as f64)),
        ("days", Json::Num(days as f64)),
        ("high_water", Json::Num(high_water as f64)),
        ("baseline_rerun_s", Json::Num(baseline_s)),
        ("stream_total_s", Json::Num(stream_s)),
        ("speedup", Json::Num(speedup)),
        ("append_elems_per_s", Json::Num(append_throughput)),
        ("resparsify_latency_s", Json::Num(resparsify_latency_s)),
        ("resparsifies", Json::Num(windows as f64)),
        ("evicted", Json::Num(stats.evicted as f64)),
        ("live_end", Json::Num(stats.live as f64)),
        ("final_value_stream", Json::Num(final_snap.value)),
        ("final_value_batch", Json::Num(batch_final_value)),
        ("rel_utility", Json::Num(rel_utility)),
    ]);
    let out = format!("{}/../BENCH_stream.json", env!("CARGO_MANIFEST_DIR"));
    std::fs::write(&out, report.pretty()).expect("write BENCH_stream.json");
    println!("(saved to {out})");

    assert!(windows >= 1, "the configuration must exercise windowed re-sparsification");
    if !smoke {
        assert!(
            speedup >= 0.9,
            "streaming regressed below ad-hoc batch re-runs: {speedup:.2}x < 0.9x \
             (the subsystem must never lose to prefix re-runs beyond noise)"
        );
        assert!(
            rel_utility >= 0.85,
            "windowed eviction cost too much utility: {rel_utility:.4} < 0.85 \
             of the batch oracle at matched k"
        );
        if std::env::var("SS_STRICT").map(|v| v == "1").unwrap_or(false) {
            assert!(
                speedup >= 1.3,
                "SS_STRICT target not met: {speedup:.2}x < 1.3x (expected on any stream \
                 long enough that prefix re-runs go quadratic; see EXPERIMENTS.md)"
            );
        }
    }
}
