//! Ablations: §3.4 improvements (importance sampling, Wei-prune pre-pass,
//! bidirectional-greedy post-reduction) and the c-sweep tradeoff.

use submodular_ss::bench::full_scale;
use submodular_ss::eval::ablation;

fn main() {
    let n = if full_scale() { 6000 } else { 1200 };
    let v = ablation::ablation_variants(n, 10);
    v.print();
    v.save("ablation_variants.json");
    let c = ablation::ablation_c_sweep(n, 10);
    c.print();
    c.save("ablation_c_sweep.json");
}
