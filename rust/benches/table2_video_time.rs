//! Reproduces paper Table 2: the 25 SumMe-like videos — #frames, |V'| and
//! per-method CPU time. CI scale uses 5 videos at 1/4 frame counts.

use submodular_ss::bench::full_scale;
use submodular_ss::data::video::{summe_suite, VideoParams};
use submodular_ss::eval::video_eval;

fn main() {
    let params = VideoParams::default();
    let suite: Vec<(String, usize)> = summe_suite(&params, 0)
        .into_iter()
        .take(if full_scale() { 25 } else { 5 })
        .map(|(n, f)| (n, if full_scale() { f } else { f / 4 }))
        .collect();
    let (t, _records) = video_eval::table2(&suite, &params, 8);
    t.print();
    t.save("table2.json");
}
