//! Reproduces paper Figures 8–11: video-summary F1/recall against
//! ground-truth-score references of varying size (8/9) and against the 15
//! per-user summaries (10/11), plus the "first 15% frames" control.

use submodular_ss::bench::full_scale;
use submodular_ss::data::video::{summe_suite, VideoParams};
use submodular_ss::eval::video_eval;

fn main() {
    let params = VideoParams::default();
    let suite: Vec<(String, usize)> = summe_suite(&params, 0)
        .into_iter()
        .take(if full_scale() { 25 } else { 4 })
        .map(|(n, f)| (n, if full_scale() { f } else { f / 4 }))
        .collect();
    let (_t2, records) = video_eval::table2(&suite, &params, 9);
    let f89 = video_eval::fig89(&records);
    f89.print();
    f89.save("fig8_9.json");
    let f1011 = video_eval::fig1011(&records);
    f1011.print();
    f1011.save("fig10_11.json");
}
