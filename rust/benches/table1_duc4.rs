//! Reproduces paper Table 1: four DUC-like named topics × reference budgets
//! {400, 200, 100, 50} words × {lazy greedy, sieve, SS}: ROUGE-2 and F1.
//! Paper shape: SS ≈ lazy greedy cell-for-cell; sieve below both.

use submodular_ss::bench::full_scale;
use submodular_ss::eval::duc;

fn main() {
    let n = if full_scale() { 1000 } else { 300 };
    let t = duc::table1(n, 7);
    t.print();
    t.save("table1.json");
}
