//! Reproduces paper Figure 4: per-day (n, time) scatter with relative
//! utility annotation, across the news stream.

use submodular_ss::bench::full_scale;
use submodular_ss::eval::news;

fn main() {
    let (days, hi) = if full_scale() { (200, 8000) } else { (15, 2000) };
    let records = news::run_days(days, 300, hi, 4);
    let t = news::fig4(&records);
    t.print();
    t.save("fig4.json");
}
