//! Perf: **tracing overhead on the SS round loop** — the observability
//! acceptance gate. Three legs over the same instance, same seed, same
//! candidate set:
//!
//! 1. `control` — `sparsify_candidates`, the untraced entry point. Its
//!    round loop is the `TRACED = false` monomorphization: tracing is
//!    compiled out entirely, not branched around.
//! 2. `traced-off` — `sparsify_candidates_traced` with a *disabled*
//!    tracer (`TRACED = true`, one relaxed atomic load per record site).
//! 3. `traced-on` — the same entry point with an enabled tracer: every
//!    round writes a span into the pre-reserved ring under a mutex.
//!
//! Bit-identity across all three legs (and against the compiled-in
//! pre-refactor reference) is asserted on **every** run, including smoke:
//! instrumentation must be provably inert. The overhead gates —
//! traced-off ≤ 2% over control, traced-on ≤ 10% — are asserted at
//! n ≥ 20 000 and skipped under `SS_SMOKE=1` (1-iteration CI runs on
//! shared runners can't resolve single-digit percentages; the smoke leg
//! still exercises all three paths and the identity asserts).
//!
//! The CPU reference backend is used rather than the sharded pool:
//! thread-pool scheduling jitter on shared hardware is larger than the
//! 2% budget being measured, and per-round tracer cost is identical on
//! both backends (the record sites live in the backend-agnostic loop).
//!
//! Emits `BENCH_trace.json` at the repository root.
//!
//! Run: `cargo bench --bench perf_trace` (SS_FULL=1 for paper scale,
//! SS_SMOKE=1 for the CI smoke that skips the machine-dependent gates).

use submodular_ss::algorithms::{
    sparsify_candidates, sparsify_candidates_reference, sparsify_candidates_traced, CpuBackend,
    SsParams,
};
use submodular_ss::bench::{bench, full_scale, Table};
use submodular_ss::trace::Tracer;
use submodular_ss::util::json::Json;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.3) { rng.f32() } else { 0.0 };
        }
    }
    m
}

fn main() {
    let smoke = std::env::var("SS_SMOKE").map(|v| v == "1").unwrap_or(false);
    let n = if full_scale() {
        50_000
    } else if smoke {
        4_000
    } else {
        20_000
    };
    let f = submodular_ss::submodular::FeatureBased::sqrt(feats(n, 16, 1));
    let backend = CpuBackend::new(&f);
    let params = SsParams::default().with_seed(7);
    let candidates: Vec<usize> = (0..n).collect();

    // bit-identity first, on every run: all three legs and the
    // compiled-in reference must agree exactly
    let want = sparsify_candidates_reference(&backend, &candidates, &params);
    let control = sparsify_candidates(&backend, &candidates, &params);
    assert_eq!(control.kept, want.kept, "untraced loop diverged from the reference");
    let off = Tracer::disabled();
    let quiet = sparsify_candidates_traced(&backend, &candidates, &params, &mut || None, &off)
        .expect("a None-returning check can never interrupt");
    assert_eq!(quiet.kept, want.kept, "a disabled tracer perturbed the kept set");
    assert!(off.is_empty(), "a disabled tracer recorded events");
    let on = Tracer::disabled();
    on.enable("perf_trace", 8192);
    let traced = sparsify_candidates_traced(&backend, &candidates, &params, &mut || None, &on)
        .expect("a None-returning check can never interrupt");
    assert_eq!(traced.kept, want.kept, "an enabled tracer perturbed the kept set");
    assert_eq!(traced.rounds, control.rounds);
    assert!(!on.is_empty(), "the enabled tracer must have recorded round spans");

    // identity holds across objectives, not just the feature-based one:
    // a facility-location instance through the same three entry points
    // (small n — this is an identity check, not a timing leg)
    let n_fl = if smoke { 600 } else { 1_500 };
    let fl = submodular_ss::submodular::FacilityLocation::from_features(&feats(n_fl, 16, 2));
    let fl_backend = CpuBackend::new(&fl);
    let fl_cands: Vec<usize> = (0..n_fl).collect();
    let fl_want = sparsify_candidates(&fl_backend, &fl_cands, &params);
    let fl_off = sparsify_candidates_traced(&fl_backend, &fl_cands, &params, &mut || None, &off)
        .expect("a None-returning check can never interrupt");
    let fl_tracer = Tracer::disabled();
    fl_tracer.enable("perf_trace_fl", 2048);
    let fl_on =
        sparsify_candidates_traced(&fl_backend, &fl_cands, &params, &mut || None, &fl_tracer)
            .expect("a None-returning check can never interrupt");
    assert_eq!(fl_off.kept, fl_want.kept, "facility location: disabled tracing diverged");
    assert_eq!(fl_on.kept, fl_want.kept, "facility location: enabled tracing diverged");

    let iters = if smoke { 1 } else { 5 };
    let r_control = bench("ss_round_untraced", 1, iters, || {
        sparsify_candidates(&backend, &candidates, &params)
    });
    let r_off = bench("ss_round_traced_off", 1, iters, || {
        sparsify_candidates_traced(&backend, &candidates, &params, &mut || None, &off).unwrap()
    });
    let r_on = bench("ss_round_traced_on", 1, iters, || {
        sparsify_candidates_traced(&backend, &candidates, &params, &mut || None, &on).unwrap()
    });

    let ratio_off = r_off.median_s / r_control.median_s;
    let ratio_on = r_on.median_s / r_control.median_s;
    let mut table = Table::new(
        "Tracing overhead on the SS round loop (ratio vs compiled-out control)",
        &["leg", "n", "median_s", "ratio", "rounds", "events"],
    );
    table.row(vec![
        "control".into(),
        n.to_string(),
        format!("{:.4}", r_control.median_s),
        "1.00".into(),
        control.rounds.to_string(),
        "-".into(),
    ]);
    table.row(vec![
        "traced-off".into(),
        n.to_string(),
        format!("{:.4}", r_off.median_s),
        format!("{ratio_off:.3}"),
        quiet.rounds.to_string(),
        "0".into(),
    ]);
    table.row(vec![
        "traced-on".into(),
        n.to_string(),
        format!("{:.4}", r_on.median_s),
        format!("{ratio_on:.3}"),
        traced.rounds.to_string(),
        on.len().to_string(),
    ]);
    table.print();

    let report = Json::obj(vec![
        ("bench", Json::Str("perf_trace".to_string())),
        ("n", Json::Num(n as f64)),
        ("smoke", Json::Num(if smoke { 1.0 } else { 0.0 })),
        ("full_scale", Json::Num(if full_scale() { 1.0 } else { 0.0 })),
        ("control_median_s", Json::Num(r_control.median_s)),
        ("traced_off_median_s", Json::Num(r_off.median_s)),
        ("traced_on_median_s", Json::Num(r_on.median_s)),
        ("ratio_off", Json::Num(ratio_off)),
        ("ratio_on", Json::Num(ratio_on)),
        ("rounds", Json::Num(control.rounds as f64)),
        ("events", Json::Num(on.len() as f64)),
        ("ring_dropped", Json::Num(on.dropped() as f64)),
    ]);
    let out = format!("{}/../BENCH_trace.json", env!("CARGO_MANIFEST_DIR"));
    std::fs::write(&out, report.pretty()).expect("write BENCH_trace.json");
    println!("(saved to {out})");

    if !smoke && n >= 20_000 {
        assert!(
            ratio_off <= 1.02,
            "disabled tracing must cost ≤ 2% over the compiled-out control \
             (measured {ratio_off:.3}x)"
        );
        assert!(
            ratio_on <= 1.10,
            "enabled tracing must cost ≤ 10% over the compiled-out control \
             (measured {ratio_on:.3}x)"
        );
    }
}
