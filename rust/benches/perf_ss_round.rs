//! Perf: the **full SS round loop** — sampling + divergence batch + prune
//! + bookkeeping, not just the kernel — on the production sharded backend,
//! per objective. The baseline leg is the pre-refactor path, compiled in:
//! `sparsify_candidates_reference` (fresh `Vec`s, index quickselect,
//! bitmap + rebuild) over a frozen copy of the old allocating sharded
//! backend (per-round `Arc<Vec>` clones, one `Vec<f32>` per shard,
//! flatten). The arena leg is `sparsify_candidates` over `ShardedBackend`'s
//! write-into path. Same RNG draws, same canonical prune policy — the two
//! legs must produce bit-identical `kept` sets, asserted every run.
//!
//! Mirrors `perf_facility_divergence`: prints ready-to-paste EXPERIMENTS.md
//! rows and emits machine-readable `BENCH_ss_round.json` at the repository
//! root so the round-loop perf trajectory is tracked from this PR on.
//!
//! What is asserted, and why (EXPERIMENTS.md §Perf has the measurement):
//! a C prototype of both paths' exact access patterns showed the n = 20k
//! round loop is already ≥95% kernel-bound on CPU, so the honest
//! end-to-end CPU win from de-allocating the loop is ~1.0–1.05×, not a
//! headline multiple — the arena's payoff is the *zero per-round
//! allocations* guarantee itself (asserted by `tests/alloc_steady_state.rs`),
//! allocator-pressure-free concurrent service load, and the accelerator
//! route where host-side loop overhead is the serial bottleneck. The
//! default assert is therefore a regression gate: the arena path must
//! never be slower than the baseline beyond noise (≥ 0.9×) at n ≥ 20 000,
//! on bit-identical outputs. `SS_STRICT=1` opts into the original ≥ 1.3×
//! target for configurations that want to chase it on real hardware.
//!
//! Run: `cargo bench --bench perf_ss_round` (SS_FULL=1 for paper scale,
//! SS_SMOKE=1 for the CI smoke that skips the machine-dependent assert).

use std::sync::Arc;

use submodular_ss::algorithms::{
    sparsify_candidates, sparsify_candidates_reference, DivergenceBackend, SsParams,
};
use submodular_ss::bench::{bench, full_scale, Table};
use submodular_ss::coordinator::{Compute, Metrics, ShardedBackend};
use submodular_ss::submodular::{BatchedDivergence, FacilityLocation, FeatureBased, Mixture};
use submodular_ss::util::json::Json;
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

/// The pre-refactor sharded backend, frozen verbatim as the baseline:
/// every round clones probes/items/probe-singletons into fresh
/// `Arc<Vec>`s, each shard materializes its own `Vec<f32>`, and the
/// results are flattened into yet another allocation.
struct BaselineSharded {
    f: Arc<dyn BatchedDivergence>,
    sing: Arc<Vec<f64>>,
    pool: Arc<ThreadPool>,
    shards: usize,
}

impl BaselineSharded {
    fn new(f: Arc<dyn BatchedDivergence>, pool: Arc<ThreadPool>, shards: usize) -> Self {
        let sing = Arc::new(f.singleton_complements());
        Self { f, sing, pool, shards }
    }
}

impl DivergenceBackend for BaselineSharded {
    fn n(&self) -> usize {
        self.f.n()
    }

    fn divergences(&self, probes: &[usize], items: &[usize]) -> Vec<f32> {
        let probes: Arc<Vec<usize>> = Arc::new(probes.to_vec());
        let items: Arc<Vec<usize>> = Arc::new(items.to_vec());
        let probe_sing: Arc<Vec<f64>> =
            Arc::new(probes.iter().map(|&u| self.sing[u]).collect());
        let f = Arc::clone(&self.f);
        let chunks = self.pool.parallel_ranges(items.len(), self.shards, move |lo, hi| {
            f.divergences_batch(&probes, &probe_sing, &items[lo..hi])
        });
        chunks.into_iter().flatten().collect()
    }

    fn importance_weights(&self, items: &[usize]) -> Vec<f64> {
        items.iter().map(|&u| self.f.singleton(u) + self.sing[u]).collect()
    }
}

fn feats(n: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.3) { rng.f32() } else { 0.0 };
        }
    }
    m
}

fn main() {
    let smoke = std::env::var("SS_SMOKE").map(|v| v == "1").unwrap_or(false);
    // feature-based carries the acceptance assert; facility/mixture are
    // capped by their O(n²)/delegation cost and reported for tracking
    let n_feat = if full_scale() {
        50_000
    } else if smoke {
        4_000
    } else {
        20_000
    };
    let n_fl = if smoke { 1_000 } else { 3_000 };
    let n_mix = if smoke { 1_500 } else { 6_000 };

    let pool = Arc::new(ThreadPool::default_for_host());
    let shards = pool.threads() * 2;
    let params = SsParams::default().with_seed(7);
    let mut table = Table::new(
        "SS round loop: fresh-allocation baseline vs arena/write-into",
        &["objective", "n", "baseline_s", "arena_s", "speedup", "rounds", "|V'|"],
    );
    let mut json_rows: Vec<Json> = Vec::new();
    let mut feat_speedup = 0.0f64;

    let cases: Vec<(&str, usize, Arc<dyn BatchedDivergence>)> = vec![
        ("features", n_feat, Arc::new(FeatureBased::sqrt(feats(n_feat, 16, 1)))),
        ("facility", n_fl, Arc::new(FacilityLocation::from_features(&feats(n_fl, 16, 2)))),
        ("mixture", n_mix, {
            let m = feats(n_mix, 16, 3);
            Arc::new(Mixture::new(vec![
                (0.7, Box::new(FeatureBased::sqrt(m.clone())) as Box<dyn BatchedDivergence>),
                (0.3, Box::new(FeatureBased::new(
                    m,
                    submodular_ss::submodular::Concave::Log1p,
                ))),
            ]))
        }),
    ];

    for (name, n, f) in cases {
        let candidates: Vec<usize> = (0..n).collect();
        let baseline = BaselineSharded::new(Arc::clone(&f), Arc::clone(&pool), shards);
        let arena = ShardedBackend::new(
            Arc::clone(&f),
            Arc::clone(&pool),
            Compute::Cpu,
            Arc::new(Metrics::new()),
        )
        .unwrap()
        .with_shards(shards);

        // bit-identity first: the two legs must agree exactly
        let want = sparsify_candidates_reference(&baseline, &candidates, &params);
        let got = sparsify_candidates(&arena, &candidates, &params);
        assert_eq!(
            got.kept, want.kept,
            "{name}: arena round loop must be bit-identical to the baseline"
        );

        let iters = if smoke { 1 } else { 3 };
        let r_base = bench(&format!("ss_round_baseline_{name}"), 1, iters, || {
            sparsify_candidates_reference(&baseline, &candidates, &params)
        });
        let r_arena = bench(&format!("ss_round_arena_{name}"), 1, iters, || {
            sparsify_candidates(&arena, &candidates, &params)
        });
        let speedup = r_base.median_s / r_arena.median_s;
        if name == "features" {
            feat_speedup = speedup;
        }
        table.row(vec![
            name.into(),
            n.to_string(),
            format!("{:.4}", r_base.median_s),
            format!("{:.4}", r_arena.median_s),
            format!("{speedup:.2}x"),
            got.rounds.to_string(),
            got.kept.len().to_string(),
        ]);
        json_rows.push(Json::obj(vec![
            ("objective", Json::Str(name.to_string())),
            ("n", Json::Num(n as f64)),
            ("probes_per_round", Json::Num(got.probes_per_round as f64)),
            ("rounds", Json::Num(got.rounds as f64)),
            ("reduced", Json::Num(got.kept.len() as f64)),
            ("baseline_median_s", Json::Num(r_base.median_s)),
            ("arena_median_s", Json::Num(r_arena.median_s)),
            ("speedup", Json::Num(speedup)),
        ]));
    }

    table.print();
    let report = Json::obj(vec![
        ("bench", Json::Str("perf_ss_round".to_string())),
        ("threads", Json::Num(pool.threads() as f64)),
        ("shards", Json::Num(shards as f64)),
        ("smoke", Json::Num(if smoke { 1.0 } else { 0.0 })),
        ("full_scale", Json::Num(if full_scale() { 1.0 } else { 0.0 })),
        ("rows", Json::Arr(json_rows)),
    ]);
    // repo root (one level above the crate), so the perf trajectory is
    // tracked alongside EXPERIMENTS.md from this PR on
    let out = format!("{}/../BENCH_ss_round.json", env!("CARGO_MANIFEST_DIR"));
    std::fs::write(&out, report.pretty()).expect("write BENCH_ss_round.json");
    println!("(saved to {out})");

    if n_feat >= 20_000 {
        assert!(
            feat_speedup >= 0.9,
            "arena round loop regressed below the fresh-allocation baseline at n ≥ 20000 \
             (measured {feat_speedup:.2}x; the loop must never be slower beyond noise)"
        );
        if std::env::var("SS_STRICT").map(|v| v == "1").unwrap_or(false) {
            assert!(
                feat_speedup >= 1.3,
                "SS_STRICT target not met: {feat_speedup:.2}x < 1.3x (expected only where \
                 the kernel is accelerated or the loop is overhead-bound; see EXPERIMENTS.md)"
            );
        }
    }
}
