//! Perf: **LSH-bucketed neighbor build** vs the exact all-pairs O(n²·d)
//! builder behind `SparseSimStore`. One leg per scale: build the top-t
//! store both ways (same explicit t, so the comparison is
//! candidate-generation only), then run the production pipeline
//! (`ss_then_greedy` over a `ShardedBackend`) on each and score the
//! LSH-built leg's pick under the exact-built objective.
//!
//! Always-on correctness gates (cheap, deterministic, run even under
//! SS_SMOKE=1):
//! * saturation bit-identity: `Lsh { tables: 1, bits: 0 }` (one bucket =
//!   all pairs) must reproduce the exact builder's store bit for bit,
//! * rel-utility ≥ 0.95: the LSH-built pipeline's summary, scored under
//!   the exact-built objective, at every scale,
//! * accounting: the LSH store's `resident_bytes` must exceed the exact
//!   store's (the hash tables are resident state — the memory gates in
//!   `perf_sparse_fl` must not be gameable by hiding the index).
//!
//! Perf gate behind `SS_STRICT=1`: LSH build ≥ 4× faster than the exact
//! build at the largest scale.
//!
//! Machine-readable `BENCH_fl_build.json` lands at the repository root.
//! Run: `cargo bench --bench perf_fl_build` (SS_FULL=1 for paper scale
//! n ∈ {5k, 20k, 80k}, SS_SMOKE=1 for the CI smoke).

use std::sync::Arc;

use submodular_ss::algorithms::{ss_then_greedy, SsParams};
use submodular_ss::bench::{full_scale, Table};
use submodular_ss::coordinator::{Compute, Metrics, ShardedBackend};
use submodular_ss::submodular::{
    BatchedDivergence, BuildStrategy, FacilityLocation, SubmodularFn,
};
use submodular_ss::util::json::Json;
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::stats::Timer;
use submodular_ss::util::vecmath::FeatureMatrix;

/// Clustered embeddings (signed): the regime hyperplane LSH banks on — a
/// row's informative neighbors share its sign pattern, so buckets align
/// with clusters and candidate generation prunes the cross-cluster work.
fn clustered_rows(n: usize, clusters: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f32>> =
        (0..clusters).map(|_| (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect()).collect();
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        let c = &centers[rng.below(clusters)];
        for j in 0..d {
            m.row_mut(i)[j] = c[j] + 0.1 * (rng.f32() - 0.5);
        }
    }
    m
}

fn pipeline_set(
    f: Arc<dyn BatchedDivergence>,
    pool: &Arc<ThreadPool>,
    k: usize,
    params: &SsParams,
) -> (f64, Vec<usize>) {
    let backend = ShardedBackend::new(
        Arc::clone(&f),
        Arc::clone(pool),
        Compute::Cpu,
        Arc::new(Metrics::new()),
    )
    .unwrap();
    let (_ss, sol) = ss_then_greedy(f.as_submodular(), &backend, k, params);
    (sol.value, sol.set)
}

fn main() {
    let smoke = std::env::var("SS_SMOKE").map(|v| v == "1").unwrap_or(false);
    let strict = std::env::var("SS_STRICT").map(|v| v == "1").unwrap_or(false);
    let scales: &[usize] = if full_scale() {
        &[5_000, 20_000, 80_000]
    } else if smoke {
        &[1_500, 5_000, 12_000]
    } else {
        &[5_000, 20_000]
    };
    let d = 16;
    let k = 10;
    let seed = 3u64;
    let params = SsParams::default().with_seed(seed);
    let pool = Arc::new(ThreadPool::default_for_host());
    let shards = pool.threads() * 2;

    // --- saturation gate: one bucket = all pairs = the exact builder ---
    let n_bit = if smoke { 1_200 } else { 2_000 };
    {
        let data = clustered_rows(n_bit, 30, d, seed);
        let t = FacilityLocation::auto_neighbors(n_bit);
        let exact = FacilityLocation::from_features_strat(
            &data,
            0,
            Some(t),
            BuildStrategy::Exact,
            Some((&pool, shards)),
        );
        let saturated = FacilityLocation::from_features_strat(
            &data,
            0,
            Some(t),
            BuildStrategy::Lsh { tables: 1, bits: 0 },
            Some((&pool, shards)),
        );
        let (ne, te, le, ce, ve) = exact.sparse_store().unwrap().export_parts();
        let (ns, ts, ls, cs, vs) = saturated.sparse_store().unwrap().export_parts();
        assert_eq!((ne, te, &le, &ce), (ns, ts, &ls, &cs), "saturated LSH shape diverged");
        assert!(
            ve.iter().zip(&vs).all(|(a, b)| a.to_bits() == b.to_bits()),
            "saturated LSH values diverged from the exact builder"
        );
        println!("saturation bit-identity @ n={n_bit}, t={t}: OK");
    }

    let mut table = Table::new(
        "LSH-bucketed neighbor build vs exact all-pairs (same explicit t)",
        &[
            "n", "t", "tables", "bits", "exact_build_s", "lsh_build_s", "speedup",
            "cand_frac", "bucket_max", "rel_utility",
        ],
    );
    let mut per_scale = Vec::new();
    let mut last_speedup = 0.0f64;
    for &n in scales {
        // k clusters, same shape as perf_sparse_fl: a k-budget summary can
        // cover the data, so rel-utility isolates the candidate-recall
        // cost instead of conflating it with budget starvation
        let data = clustered_rows(n, k, d, 11);
        let t = FacilityLocation::auto_neighbors(n);
        let (tables, bits) = BuildStrategy::auto_lsh_params(n);

        let timer = Timer::new();
        let exact = FacilityLocation::from_features_strat(
            &data,
            0,
            Some(t),
            BuildStrategy::Exact,
            Some((&pool, shards)),
        );
        let exact_build_s = timer.elapsed_s();

        let timer = Timer::new();
        let lsh = FacilityLocation::from_features_strat(
            &data,
            0,
            Some(t),
            BuildStrategy::Lsh { tables, bits },
            Some((&pool, shards)),
        );
        let lsh_build_s = timer.elapsed_s();
        last_speedup = exact_build_s / lsh_build_s.max(1e-9);

        let store = lsh.sparse_store().unwrap();
        let (cands, bucket_max) = store.lsh_stats().unwrap();
        let cand_frac = cands as f64 / (n as f64 * (n as f64 - 1.0));
        assert!(
            lsh.resident_bytes() > exact.resident_bytes(),
            "n={n}: resident_bytes must account for the hash tables"
        );

        let (exact_value, _) =
            pipeline_set(Arc::new(exact.clone()), &pool, k, &params);
        let (_, lsh_set) = pipeline_set(Arc::new(lsh.clone()), &pool, k, &params);
        let rel_utility = exact.eval(&lsh_set) / exact_value;
        assert!(
            rel_utility >= 0.95,
            "n={n}: LSH candidate recall cost too much utility: {rel_utility:.4}"
        );

        table.row(vec![
            n.to_string(),
            t.to_string(),
            tables.to_string(),
            bits.to_string(),
            format!("{exact_build_s:.3}"),
            format!("{lsh_build_s:.3}"),
            format!("{last_speedup:.2}x"),
            format!("{cand_frac:.4}"),
            bucket_max.to_string(),
            format!("{rel_utility:.4}"),
        ]);
        per_scale.push(Json::obj(vec![
            ("n", Json::Num(n as f64)),
            ("t", Json::Num(t as f64)),
            ("tables", Json::Num(tables as f64)),
            ("bits", Json::Num(bits as f64)),
            ("exact_build_s", Json::Num(exact_build_s)),
            ("lsh_build_s", Json::Num(lsh_build_s)),
            ("build_speedup", Json::Num(last_speedup)),
            ("lsh_candidates", Json::Num(cands as f64)),
            ("candidate_fraction", Json::Num(cand_frac)),
            ("lsh_bucket_max", Json::Num(bucket_max as f64)),
            ("rel_utility", Json::Num(rel_utility)),
        ]));
    }
    table.print();

    if strict {
        assert!(
            last_speedup >= 4.0,
            "SS_STRICT target not met: LSH build {last_speedup:.2}x < 4x over exact at the \
             top scale (expected once bucket candidate generation displaces the O(n²·d) scan)"
        );
    }

    let report = Json::obj(vec![
        ("bench", Json::Str("perf_fl_build".to_string())),
        ("threads", Json::Num(pool.threads() as f64)),
        ("smoke", Json::Num(if smoke { 1.0 } else { 0.0 })),
        ("full_scale", Json::Num(if full_scale() { 1.0 } else { 0.0 })),
        ("saturation_bit_identity_n", Json::Num(n_bit as f64)),
        ("saturation_bit_identity", Json::Bool(true)),
        ("build_speedup_top", Json::Num(last_speedup)),
        ("scales", Json::Arr(per_scale)),
    ]);
    let out = format!("{}/../BENCH_fl_build.json", env!("CARGO_MANIFEST_DIR"));
    std::fs::write(&out, report.pretty()).expect("write BENCH_fl_build.json");
    println!("(saved to {out})");
}
