//! Reproduces paper Figure 3: distribution of relative utility, ROUGE-2 and
//! F1 across a stream of news days (paper: 3823 NYT days; here a synthetic
//! stream — 20 days CI / 200 days SS_FULL).

use submodular_ss::bench::full_scale;
use submodular_ss::eval::news;

fn main() {
    let (days, hi) = if full_scale() { (200, 8000) } else { (20, 1500) };
    let records = news::run_days(days, 300, hi, 3);
    let t = news::fig3(&records);
    t.print();
    t.save("fig3.json");
}
