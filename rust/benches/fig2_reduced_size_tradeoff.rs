//! Reproduces paper Figure 2: relative utility and SS time against |V'|,
//! swept via r ∈ {2,4,…,20} with c = 8 (the paper's exact sweep).

use submodular_ss::bench::full_scale;
use submodular_ss::eval::news;

fn main() {
    let n = if full_scale() { 10000 } else { 1500 };
    let t = news::fig2(n, 2);
    t.print();
    t.save("fig2.json");
}
