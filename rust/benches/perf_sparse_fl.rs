//! Perf: **sparse facility location** (top-t neighbor store) vs the dense
//! n² similarity matrix it demotes to a small-n oracle. One leg per scale:
//! build the store, then run the production batch pipeline
//! (`ss_then_greedy` over a `ShardedBackend`) on top of it. The dense leg
//! only runs where its matrix actually fits (`n ≤ DENSE_CAP`) — above
//! that, the dense column reports the *virtual* n²·4 B footprint, which is
//! exactly the point: the sparse store is what makes those scales exist.
//!
//! Always-on correctness gates (cheap, deterministic, run even under
//! SS_SMOKE=1):
//! * bit-identity at `t = n−1`: identical SS kept set, greedy commits and
//!   value bits to the dense oracle through the sharded pipeline,
//! * memory: at the largest scale the sparse store must be ≥ 4× smaller
//!   than the (virtual) dense matrix.
//!
//! Perf gate behind `SS_STRICT=1`: sparse end-to-end (build + pipeline)
//! ≥ 1.3× dense end-to-end at the largest scale where both legs run.
//!
//! Machine-readable `BENCH_sparse_fl.json` lands at the repository root.
//! Run: `cargo bench --bench perf_sparse_fl` (SS_FULL=1 for paper scale
//! n ∈ {5k, 20k, 80k}, SS_SMOKE=1 for the CI smoke).

use std::sync::Arc;

use submodular_ss::algorithms::{ss_then_greedy, SsParams};
use submodular_ss::bench::{full_scale, Table};
use submodular_ss::coordinator::{Compute, Metrics, ShardedBackend};
use submodular_ss::submodular::{BatchedDivergence, FacilityLocation};
use submodular_ss::util::json::Json;
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::stats::Timer;
use submodular_ss::util::vecmath::FeatureMatrix;

/// Clustered embeddings (signed): each row's informative similarities are
/// its cluster mates, the regime facility location models and top-t
/// truncation is near-lossless in.
fn clustered_rows(n: usize, clusters: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f32>> =
        (0..clusters).map(|_| (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect()).collect();
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        let c = &centers[rng.below(clusters)];
        for j in 0..d {
            m.row_mut(i)[j] = c[j] + 0.1 * (rng.f32() - 0.5);
        }
    }
    m
}

/// Largest n whose dense f32 matrix we are willing to materialize for the
/// baseline leg (8192² · 4 B = 256 MiB).
const DENSE_CAP: usize = 8_192;

struct Leg {
    build_s: f64,
    pipe_s: f64,
    value: f64,
    set: Vec<usize>,
}

fn run_pipeline(
    f: Arc<dyn BatchedDivergence>,
    pool: &Arc<ThreadPool>,
    k: usize,
    params: &SsParams,
) -> (f64, f64, Vec<usize>) {
    let t = Timer::new();
    let backend = ShardedBackend::new(
        Arc::clone(&f),
        Arc::clone(pool),
        Compute::Cpu,
        Arc::new(Metrics::new()),
    )
    .unwrap();
    let (_ss, sol) = ss_then_greedy(f.as_submodular(), &backend, k, params);
    (t.elapsed_s(), sol.value, sol.set)
}

fn main() {
    let smoke = std::env::var("SS_SMOKE").map(|v| v == "1").unwrap_or(false);
    let strict = std::env::var("SS_STRICT").map(|v| v == "1").unwrap_or(false);
    let scales: &[usize] = if full_scale() {
        &[5_000, 20_000, 80_000]
    } else if smoke {
        &[1_500, 5_000, 12_000]
    } else {
        &[5_000, 20_000]
    };
    let d = 16;
    let k = 10;
    let seed = 3u64;
    let params = SsParams::default().with_seed(seed);
    let pool = Arc::new(ThreadPool::default_for_host());
    let shards = pool.threads() * 2;

    // --- bit-identity gate: t = n−1 must reproduce dense exactly ---
    let n_bit = if smoke { 1_200 } else { 2_000 };
    {
        let data = clustered_rows(n_bit, 30, d, seed);
        let dense: Arc<dyn BatchedDivergence> =
            Arc::new(FacilityLocation::from_features_dense(&data));
        let sparse: Arc<dyn BatchedDivergence> = Arc::new(FacilityLocation::from_features_with(
            &data,
            0,
            Some(n_bit - 1),
            Some((&pool, shards)),
        ));
        let (_, vd, sd) = run_pipeline(dense, &pool, k, &params);
        let (_, vs, ss) = run_pipeline(sparse, &pool, k, &params);
        assert_eq!(sd, ss, "t = n−1 must select the identical summary");
        assert_eq!(vd.to_bits(), vs.to_bits(), "t = n−1 must be bit-identical to dense");
        println!("bit-identity @ n={n_bit}, t=n−1: OK (value {vd:.6})");
    }

    let mut table = Table::new(
        "Sparse top-t store vs dense n² matrix (build + ss_then_greedy)",
        &[
            "n", "t", "dense_MB", "sparse_MB", "mem_red", "dense_e2e_s", "sparse_e2e_s",
            "speedup", "rel_utility",
        ],
    );
    let mut per_scale = Vec::new();
    let mut last_mem_reduction = 0.0f64;
    let mut last_both_speedup: Option<f64> = None;
    for &n in scales {
        // k clusters: the regime where a k-budget summary can cover the
        // data and the truncation cost is the honest signal (with more
        // clusters than k, BOTH legs leave clusters uncovered and the
        // ratio measures ambient-similarity loss instead — see
        // EXPERIMENTS.md §Sparse facility location for the measured sweep)
        let data = clustered_rows(n, k, d, 11);
        let t_budget = FacilityLocation::auto_neighbors(n);

        let timer = Timer::new();
        let sparse_fl =
            FacilityLocation::from_features_with(&data, 0, None, Some((&pool, shards)));
        let sparse_build_s = timer.elapsed_s();
        let sparse_bytes = sparse_fl.resident_bytes();
        let dense_bytes = n * n * std::mem::size_of::<f32>();
        last_mem_reduction = dense_bytes as f64 / sparse_bytes as f64;

        let (sparse_pipe_s, sparse_value, sparse_set) =
            run_pipeline(Arc::new(sparse_fl), &pool, k, &params);
        let sparse = Leg {
            build_s: sparse_build_s,
            pipe_s: sparse_pipe_s,
            value: sparse_value,
            set: sparse_set,
        };

        let dense = (n <= DENSE_CAP).then(|| {
            let timer = Timer::new();
            let fl = FacilityLocation::from_features_dense(&data);
            let build_s = timer.elapsed_s();
            let fl = Arc::new(fl);
            let (pipe_s, value, set) =
                run_pipeline(Arc::clone(&fl) as Arc<dyn BatchedDivergence>, &pool, k, &params);
            // score the sparse leg's pick under the dense objective: the
            // honest utility cost of truncation
            use submodular_ss::submodular::SubmodularFn;
            let sparse_under_dense = fl.eval(&sparse.set);
            (Leg { build_s, pipe_s, value, set }, sparse_under_dense)
        });

        let sparse_e2e = sparse.build_s + sparse.pipe_s;
        let (dense_e2e, speedup, rel_utility) = match &dense {
            Some((leg, sud)) => {
                let e2e = leg.build_s + leg.pipe_s;
                let sp = e2e / sparse_e2e;
                last_both_speedup = Some(sp);
                (Some(e2e), Some(sp), Some(sud / leg.value))
            }
            None => (None, None, None),
        };

        table.row(vec![
            n.to_string(),
            t_budget.to_string(),
            format!("{:.1}", dense_bytes as f64 / 1e6),
            format!("{:.1}", sparse_bytes as f64 / 1e6),
            format!("{last_mem_reduction:.0}x"),
            dense_e2e.map_or("-".into(), |s| format!("{s:.3}")),
            format!("{sparse_e2e:.3}"),
            speedup.map_or("-".into(), |s| format!("{s:.2}x")),
            rel_utility.map_or("-".into(), |r| format!("{r:.4}")),
        ]);
        per_scale.push(Json::obj(vec![
            ("n", Json::Num(n as f64)),
            ("t", Json::Num(t_budget as f64)),
            ("dense_bytes_virtual", Json::Num(dense_bytes as f64)),
            ("sparse_bytes", Json::Num(sparse_bytes as f64)),
            ("mem_reduction", Json::Num(last_mem_reduction)),
            ("sparse_build_s", Json::Num(sparse.build_s)),
            ("sparse_pipeline_s", Json::Num(sparse.pipe_s)),
            ("sparse_value", Json::Num(sparse.value)),
            (
                "dense_e2e_s",
                dense_e2e.map_or(Json::Null, Json::Num),
            ),
            ("e2e_speedup", speedup.map_or(Json::Null, Json::Num)),
            ("rel_utility", rel_utility.map_or(Json::Null, Json::Num)),
        ]));
        // C-prototype measurements put this at 0.95–1.00 for the gated
        // scales (dense leg ≤ DENSE_CAP); 0.85 leaves headroom for the SS
        // pass's randomization on shared runners
        if let Some(r) = rel_utility {
            assert!(
                r >= 0.85,
                "n={n}: truncation cost too much utility under the dense objective: {r:.4}"
            );
        }
    }
    table.print();

    // --- memory gate at the largest scale ---
    assert!(
        last_mem_reduction >= 4.0,
        "sparse store must be ≥4× smaller than dense at the top scale, got {last_mem_reduction:.1}x"
    );
    if strict {
        let sp = last_both_speedup.expect("a scale with both legs must have run");
        assert!(
            sp >= 1.3,
            "SS_STRICT target not met: sparse end-to-end {sp:.2}x < 1.3x over dense \
             (expected once the O(n·t) gain kernels displace the O(n²) scans)"
        );
    }

    let report = Json::obj(vec![
        ("bench", Json::Str("perf_sparse_fl".to_string())),
        ("threads", Json::Num(pool.threads() as f64)),
        ("smoke", Json::Num(if smoke { 1.0 } else { 0.0 })),
        ("full_scale", Json::Num(if full_scale() { 1.0 } else { 0.0 })),
        ("dense_cap", Json::Num(DENSE_CAP as f64)),
        ("bit_identity_n", Json::Num(n_bit as f64)),
        ("bit_identity", Json::Bool(true)),
        ("mem_reduction_top", Json::Num(last_mem_reduction)),
        ("scales", Json::Arr(per_scale)),
    ]);
    let out = format!("{}/../BENCH_sparse_fl.json", env!("CARGO_MANIFEST_DIR"));
    std::fs::write(&out, report.pretty()).expect("write BENCH_sparse_fl.json");
    println!("(saved to {out})");
}
