//! Perf: the **durability tax** and the **recovery-time vs
//! checkpoint-interval** trade-off of durable streaming sessions.
//!
//! Leg 1 streams the same clustered feed through a plain session and a
//! durable one (file-backed WAL, fsync per record) and reports the append
//! throughput of each — the per-record logging overhead in one number.
//! Leg 2 "crashes" durable sessions run at several checkpoint intervals
//! (drop without close) and times `recover_with_report` over the surviving
//! files: a short interval pays checkpoint writes during ingest to keep
//! the replayed WAL tail small; interval 0 (manual checkpoints only — here
//! just the open checkpoint) replays the entire stream. Every recovered
//! session's Final snapshot is asserted **bit-identical** to the
//! uninterrupted plain session — the crash-exactness contract, measured
//! at bench scale rather than test scale.
//!
//! Machine-readable `BENCH_durability.json` lands at the repository root.
//! The WAL/checkpoint files live under a per-process temp directory that
//! is removed before exit.
//!
//! Run: `cargo bench --bench perf_durability` (SS_FULL=1 for paper scale,
//! SS_SMOKE=1 for the CI smoke).

use std::sync::Arc;

use submodular_ss::algorithms::SsParams;
use submodular_ss::bench::{full_scale, Table};
use submodular_ss::coordinator::Metrics;
use submodular_ss::stream::{
    DurabilityConfig, FileStore, FlushPolicy, ObjectiveSpec, SnapshotMode, StreamConfig,
    StreamSession,
};
use submodular_ss::submodular::Concave;
use submodular_ss::util::json::Json;
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::stats::Timer;
use submodular_ss::util::vecmath::FeatureMatrix;

fn clustered_rows(n: usize, clusters: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f32>> = (0..clusters)
        .map(|_| (0..d).map(|_| if rng.bool(0.4) { rng.f32() * 3.0 } else { 0.0 }).collect())
        .collect();
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        let c = &centers[rng.below(clusters)];
        for j in 0..d {
            m.row_mut(i)[j] = (c[j] + 0.05 * rng.f32()).max(0.0);
        }
    }
    m
}

fn main() {
    let smoke = std::env::var("SS_SMOKE").map(|v| v == "1").unwrap_or(false);
    let (batches, per_batch) = if full_scale() {
        (24usize, 2_000usize)
    } else if smoke {
        (6, 300)
    } else {
        (16, 1_000)
    };
    let d = 16;
    let k = 8;
    let n_total = batches * per_batch;
    let seed = 11u64;
    let params = SsParams::default().with_seed(seed);
    let high_water = (2 * per_batch / 3).max(64);
    let kind = ObjectiveSpec::Features(Concave::Sqrt);
    let cfg = StreamConfig::new(k).with_ss(params).with_high_water(high_water);

    let data = clustered_rows(n_total, 25, d, seed);
    let pool = Arc::new(ThreadPool::default_for_host());
    let chunk = |i: usize| &data.data()[i * per_batch * d..(i + 1) * per_batch * d];

    // --- plain session: the no-durability baseline ---
    let mut plain = StreamSession::new(
        kind,
        d,
        cfg.clone(),
        Arc::clone(&pool),
        Arc::new(Metrics::new()),
    )
    .unwrap();
    let t = Timer::new();
    for i in 0..batches {
        plain.append(chunk(i)).unwrap();
    }
    let plain_append_s = t.elapsed_s();
    let oracle = plain.snapshot_summary(SnapshotMode::Final).unwrap();
    plain.close();

    let dir = std::env::temp_dir().join(format!("ss_perf_durability_{}", std::process::id()));
    let mut table = Table::new(
        "Durable streams: append tax (file WAL) and recovery vs checkpoint interval / flush policy",
        &[
            "leg", "ckpt_every", "flush", "append_s", "elems/s", "overhead", "recover_s",
            "replayed", "ckpt_seq",
        ],
    );
    let plain_tput = n_total as f64 / plain_append_s;
    table.row(vec![
        "plain".into(),
        "-".into(),
        "-".into(),
        format!("{plain_append_s:.3}"),
        format!("{plain_tput:.0}"),
        "1.00x".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);

    // --- durable legs: same feed, crash, recover. The first three vary
    // the checkpoint interval at fsync-per-record; the last two hold the
    // interval and relax the flush policy to group commit, pricing the
    // fsync itself (drop-as-crash is a *process* crash, so the written-
    // but-unflushed tail survives and bit-identity still must hold) ---
    let leg_specs: &[(u64, FlushPolicy, &str)] = &[
        (0, FlushPolicy::EveryRecord, "record"),
        (4, FlushPolicy::EveryRecord, "record"),
        (16, FlushPolicy::EveryRecord, "record"),
        (16, FlushPolicy::EveryN(8), "every8"),
        (16, FlushPolicy::EveryN(64), "every64"),
    ];
    let mut legs = Vec::new();
    for &(interval, policy, flush_label) in leg_specs {
        let leg_dir = dir.join(format!("interval_{interval}_{flush_label}"));
        let dcfg = DurabilityConfig::default()
            .with_checkpoint_interval(interval)
            .with_flush_policy(policy);
        let mut sess = StreamSession::open_durable(
            kind,
            d,
            cfg.clone(),
            Arc::clone(&pool),
            Arc::new(Metrics::new()),
            Box::new(FileStore::open(&leg_dir).expect("open bench store")),
            dcfg,
        )
        .unwrap();
        let t = Timer::new();
        for i in 0..batches {
            sess.append(chunk(i)).unwrap();
        }
        let append_s = t.elapsed_s();
        drop(sess); // crash: no close, only the files survive

        let t = Timer::new();
        let (mut rec, report) = StreamSession::recover_with_report(
            Arc::clone(&pool),
            Arc::new(Metrics::new()),
            Box::new(FileStore::open(&leg_dir).expect("reopen bench store")),
            dcfg,
        )
        .expect("recover bench session");
        let recover_s = t.elapsed_s();

        // crash-exactness at bench scale: the recovered session's exact
        // snapshot must be bit-identical to the uninterrupted baseline
        let snap = rec.snapshot_summary(SnapshotMode::Final).unwrap();
        assert_eq!(snap.summary, oracle.summary, "interval {interval}: summary diverged");
        assert_eq!(
            snap.value.to_bits(),
            oracle.value.to_bits(),
            "interval {interval}: value bits diverged"
        );
        rec.close();

        let overhead = append_s / plain_append_s;
        table.row(vec![
            "durable".into(),
            interval.to_string(),
            flush_label.into(),
            format!("{append_s:.3}"),
            format!("{:.0}", n_total as f64 / append_s),
            format!("{overhead:.2}x"),
            format!("{recover_s:.4}"),
            report.replayed_records.to_string(),
            report.checkpoint_seq.to_string(),
        ]);
        legs.push(Json::obj(vec![
            ("checkpoint_interval", Json::Num(interval as f64)),
            ("flush_policy", Json::Str(flush_label.to_string())),
            ("append_s", Json::Num(append_s)),
            ("append_elems_per_s", Json::Num(n_total as f64 / append_s)),
            ("overhead_vs_plain", Json::Num(overhead)),
            ("recover_s", Json::Num(recover_s)),
            ("replayed_records", Json::Num(report.replayed_records as f64)),
            ("checkpoint_seq", Json::Num(report.checkpoint_seq as f64)),
            ("torn_tail_truncations", Json::Num(report.torn_tail_truncations as f64)),
        ]));
    }
    table.print();
    let _ = std::fs::remove_dir_all(&dir); // temp-dir hygiene

    let report = Json::obj(vec![
        ("bench", Json::Str("perf_durability".to_string())),
        ("threads", Json::Num(pool.threads() as f64)),
        ("smoke", Json::Num(if smoke { 1.0 } else { 0.0 })),
        ("full_scale", Json::Num(if full_scale() { 1.0 } else { 0.0 })),
        ("n_total", Json::Num(n_total as f64)),
        ("batches", Json::Num(batches as f64)),
        ("high_water", Json::Num(high_water as f64)),
        ("plain_append_s", Json::Num(plain_append_s)),
        ("plain_elems_per_s", Json::Num(plain_tput)),
        ("durable_legs", Json::Arr(legs)),
    ]);
    let out = format!("{}/../BENCH_durability.json", env!("CARGO_MANIFEST_DIR"));
    std::fs::write(&out, report.pretty()).expect("write BENCH_durability.json");
    println!("(saved to {out})");
}
