//! Perf micro-bench: the SS hot loop (divergence batches) across backends —
//! single-thread CPU, sharded CPU, PJRT tiles. The §Perf numbers in
//! EXPERIMENTS.md come from this target.

use std::sync::Arc;

use submodular_ss::algorithms::{CpuBackend, DivergenceBackend};
use submodular_ss::bench::{bench, full_scale};
use submodular_ss::coordinator::{Compute, Metrics, ShardedBackend};
use submodular_ss::runtime;
use submodular_ss::submodular::FeatureBased;
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

fn instance(n: usize, d: usize, seed: u64) -> Arc<FeatureBased> {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.3) { rng.f32() } else { 0.0 };
        }
    }
    Arc::new(FeatureBased::sqrt(m))
}

fn main() {
    let (n, d, probes) = if full_scale() { (8000, 256, 104) } else { (2000, 256, 88) };
    let f = instance(n, d, 1);
    let probe_idx: Vec<usize> = (0..probes).collect();
    let items: Vec<usize> = (probes..n).collect();
    let iters = if full_scale() { 5 } else { 3 };

    let cpu = CpuBackend::new(f.as_ref());
    let r_cpu = bench("cpu_reference", 1, iters, || cpu.divergences(&probe_idx, &items));

    // perf-pass kernel: per-probe cached g(u) rows (see EXPERIMENTS.md §Perf)
    let sing: Vec<f64> = probe_idx.iter().map(|&u| cpu.singletons()[u]).collect();
    let r_blk = bench("cpu_blocked_kernel", 1, iters, || {
        f.divergences_block(&probe_idx, &sing, &items)
    });

    let pool = Arc::new(ThreadPool::new(2, 16));
    let metrics = Arc::new(Metrics::new());
    let sharded = ShardedBackend::new(Arc::clone(&f), pool, Compute::Cpu, metrics).unwrap();
    let r_sh = bench("sharded_cpu_2workers", 1, iters, || sharded.divergences(&probe_idx, &items));

    println!(
        "throughput: cpu {:.2} | blocked {:.2} | sharded {:.2} Mpair/s",
        (probes * items.len()) as f64 / r_cpu.median_s / 1e6,
        (probes * items.len()) as f64 / r_blk.median_s / 1e6,
        (probes * items.len()) as f64 / r_sh.median_s / 1e6,
    );

    match runtime::start_default(1) {
        Ok((_svc, rt)) => {
            let backend = runtime::PjrtBackend::new(f.as_ref(), Arc::clone(&rt)).unwrap();
            let r = bench("pjrt_tiled", 1, iters, || backend.divergences(&probe_idx, &items));
            let stats = rt.stats();
            println!(
                "pjrt: {:.2} Mpair/s over {} tile calls ({} items)",
                (probes * items.len()) as f64 / r.median_s / 1e6,
                stats.edge_weight_calls,
                stats.items_processed
            );
        }
        Err(e) => println!("pjrt skipped: {e}"),
    }
}
