//! **End-to-end driver** (DESIGN.md §5, EXPERIMENTS.md §E2E): the full
//! three-layer stack on a realistic serving workload.
//!
//! * Layer 1/2: the AOT-compiled Pallas kernels (edge weights, singleton
//!   complements, utility) loaded from `artifacts/` — built once by
//!   `make artifacts`, Python not involved here.
//! * Layer 3: the summarization service — bounded request queue, worker
//!   threads, SS leader sharding divergence tiles through the shared PJRT
//!   executor, lazy-greedy on the reduced set.
//!
//! A stream of daily-news summarization requests (varying n) is pushed
//! through the service twice — CPU backend, then PJRT backend — and the
//! demo reports per-request relative utility plus latency/throughput
//! percentiles. Falls back to CPU-only if artifacts are missing.
//!
//! Run: `make artifacts && cargo run --release --example service_demo`


use std::time::Duration;

use submodular_ss::algorithms::{lazy_greedy, SsParams};
use submodular_ss::coordinator::{
    JobOptions, Objective, ServiceConfig, ServiceError, SummarizationService, SummarizeRequest,
};
use submodular_ss::data::{CorpusParams, NewsGenerator, VideoParams};
use submodular_ss::runtime;
use submodular_ss::stream::{SnapshotMode, StreamConfig};
use submodular_ss::submodular::{Concave, FacilityLocation, FeatureBased, SubmodularFn};
use submodular_ss::util::stats::{Samples, Timer};
use submodular_ss::ObjectiveSpec;

fn main() {
    let requests = 10usize;
    let seed = 11u64;
    let generator = NewsGenerator::new(CorpusParams::default(), seed);

    // pre-generate the workload (sizes 400..1600) and full-greedy references
    println!("generating {requests} summarization requests...");
    let days: Vec<_> = (0..requests)
        .map(|i| generator.day(400 + (i * 133) % 1200, 0, seed + i as u64))
        .collect();
    let references: Vec<f64> = days
        .iter()
        .map(|d| {
            let f = FeatureBased::sqrt(d.feats.clone());
            let all: Vec<usize> = (0..f.n()).collect();
            lazy_greedy(&f, &all, d.k).value
        })
        .collect();

    let pjrt = match runtime::start_default(1) {
        Ok((svc, rt)) => {
            std::mem::forget(svc);
            Some(rt)
        }
        Err(e) => {
            eprintln!("PJRT unavailable ({e}); running CPU-only. Run `make artifacts` first.");
            None
        }
    };

    for (label, use_pjrt) in [("CPU backend", false), ("PJRT backend", true)] {
        if use_pjrt && pjrt.is_none() {
            continue;
        }
        println!("\n=== {label} ===");
        let svc = SummarizationService::start(
            ServiceConfig { workers: 2, queue_depth: 16, compute_threads: 2 },
            pjrt.clone(),
        );
        let wall = Timer::new();
        let tickets: Vec<_> = days
            .iter()
            .enumerate()
            .map(|(i, day)| {
                svc.submit(
                    SummarizeRequest::features(
                        day.feats.clone(),
                        day.k,
                        SsParams::default().with_seed(seed + i as u64),
                    )
                    .with_pjrt(use_pjrt),
                )
            })
            .collect();
        let mut latencies = Samples::new();
        let mut rels = Samples::new();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait().expect("request failed");
            let rel = r.value / references[i];
            latencies.push(r.latency_s);
            rels.push(rel);
            println!(
                "req {i:>2}: n={:>5} |V'|={:>4} rel-utility={:.4} latency={:.3}s",
                r.n, r.reduced, rel, r.latency_s
            );
        }
        let total = wall.elapsed_s();
        println!(
            "throughput {:.2} req/s | latency p50 {:.3}s p95 {:.3}s | rel-utility median {:.4} min {:.4}",
            requests as f64 / total,
            latencies.percentile(50.0),
            latencies.percentile(95.0),
            rels.median(),
            rels.percentile(0.0),
        );
        println!("{}", svc.metrics_json());
        assert!(rels.percentile(0.0) > 0.85, "E2E quality floor violated");
    }
    // --- video-style facility-location requests through the same service ---
    // the sharded pipeline is objective-generic: submit a dense-similarity
    // representativeness objective (the paper's §4.3 workload shape) and it
    // runs the blocked facility-location kernel on the CPU shards.
    println!("\n=== facility location (video frames) ===");
    let svc = SummarizationService::start(
        ServiceConfig { workers: 2, queue_depth: 16, compute_threads: 2 },
        None,
    );
    let frames = 600usize;
    let k = frames * 15 / 100;
    let video = submodular_ss::data::generate_video(
        "service-demo clip",
        frames,
        &VideoParams::default(),
        seed,
    );
    let fl = FacilityLocation::from_features(&video.feats);
    let all: Vec<usize> = (0..frames).collect();
    let full = lazy_greedy(&fl, &all, k);
    let resp = svc
        .submit(SummarizeRequest {
            objective: Objective::FacilityLocation(fl),
            k,
            params: SsParams::default().with_seed(seed).with_min_keep(k + k / 2),
            use_pjrt: false,
        })
        .wait()
        .expect("facility-location request failed");
    let rel = resp.value / full.value;
    println!(
        "video: {frames} frames -> |V'|={} -> k={k} thumbnails | rel-utility={rel:.4} latency={:.3}s",
        resp.reduced, resp.latency_s
    );
    assert!(rel > 0.85, "facility-location E2E quality floor violated");

    // --- the job API: deadlines, cancellation, copy-on-snapshot streams ---
    // Every unit of work is a job with a Ticket: a deadline the request
    // cannot make sheds it (at dequeue or between SS rounds) without
    // burning the compute pool, a cancel does the same on demand, and a
    // stream's Final snapshot runs as a pool job while appends continue.
    println!("\n=== job API (deadlines / cancellation / snapshot jobs) ===");
    let svc = SummarizationService::start(
        ServiceConfig { workers: 1, queue_depth: 16, compute_threads: 2 },
        None,
    );
    let day = generator.day(1200, 0, seed + 99);
    let impossible = svc.submit_with(
        SummarizeRequest::features(day.feats.clone(), day.k, SsParams::default().with_seed(seed)),
        JobOptions::default().with_timeout(Duration::from_millis(1)),
    );
    match impossible.wait() {
        Err(ServiceError::DeadlineExceeded) => println!("1ms-deadline request shed, as it must be"),
        other => println!("unexpectedly fast hardware: {other:?}"),
    }

    let id = svc
        .open_stream(
            ObjectiveSpec::Features(Concave::Sqrt),
            day.feats.d,
            StreamConfig::new(day.k).with_ss(SsParams::default().with_seed(seed)),
        )
        .expect("open stream");
    svc.append(id, day.feats.data()).expect("append day");
    let live_at_submit = 1200;
    let ticket = svc.submit_snapshot(id, SnapshotMode::Final).expect("submit snapshot job");
    // appends keep landing while the Final snapshot job runs on the pool
    let day2 = generator.day(400, 0, seed + 100);
    svc.append(id, day2.feats.data()).expect("append during in-flight snapshot");
    let snap = ticket.wait().expect("snapshot job");
    println!(
        "snapshot job: f(S) = {:.3} over {} live elements (clone-time view; \
         {} more rows appended while it ran)",
        snap.value,
        snap.live,
        1200 + 400 - live_at_submit,
    );
    assert_eq!(snap.live, live_at_submit, "copy-on-snapshot freezes the clone-time view");
    let stats = svc.close(id).expect("close stream");
    assert_eq!(stats.appends, 1600);
    println!("{}", svc.metrics_json());

    // --- observability: structured spans, Chrome trace, flight recorder ---
    // Enable the service-scope tracer, run one summarize job through it,
    // and dump the span tree (job -> ss_round -> cohort / kernel_dispatch)
    // as a Chrome trace-event document loadable in Perfetto or
    // chrome://tracing. Streams additionally keep an always-on bounded
    // flight recorder, dumpable through the job API even after quarantine.
    println!("\n=== observability (Chrome trace / flight recorder) ===");
    let tracer = svc.metrics().tracer();
    tracer.enable("service", 4096);
    let day3 = generator.day(900, 0, seed + 101);
    let traced = svc
        .submit(SummarizeRequest::features(
            day3.feats.clone(),
            day3.k,
            SsParams::default().with_seed(seed),
        ))
        .wait()
        .expect("traced request");
    assert!(!tracer.is_empty(), "the traced job must leave spans behind");
    let doc = submodular_ss::trace::export::to_chrome_trace(&[tracer.as_ref()]);
    let out = std::env::temp_dir().join("service_demo_trace.json");
    std::fs::write(&out, doc.to_string()).expect("write chrome trace");
    println!(
        "traced summarize job: n={} -> |V'|={} | {} spans captured -> {}",
        traced.n,
        traced.reduced,
        tracer.len(),
        out.display(),
    );

    let id = svc
        .open_stream(
            ObjectiveSpec::Features(Concave::Sqrt),
            day3.feats.d,
            // a low high-water forces a windowed re-sparsification, so the
            // recorder has window + ss_round spans to show
            StreamConfig::new(day3.k)
                .with_ss(SsParams::default().with_seed(seed))
                .with_high_water(300),
        )
        .expect("open traced stream");
    svc.append(id, day3.feats.data()).expect("append to traced stream");
    let dump = svc.submit_flight_dump(id).expect("submit dump job").wait().expect("dump job");
    let n_events = dump.get("events").and_then(|e| e.as_arr()).map(|a| a.len()).unwrap_or(0);
    println!(
        "flight recorder: scope={} holds {n_events} events (ring capacity {})",
        dump.get("scope").and_then(|s| s.as_str()).unwrap_or("?"),
        dump.get("capacity").and_then(|c| c.as_f64()).unwrap_or(0.0),
    );
    assert!(n_events > 0, "a stream with appends must have flight-recorder events");
    svc.close(id).expect("close traced stream");

    println!("\nservice_demo OK — full stack (Pallas kernels via PJRT under a Rust coordinator) validated");
}
