//! Daily news summarization — the paper's §4.1/§4.2 workload on the
//! NYT-like synthetic corpus: generate a day of news, summarize it with
//! lazy greedy, sieve-streaming, and SS+lazy-greedy, and score all three
//! against the day's reference summary with ROUGE-2.
//!
//! Run: `cargo run --release --example news_daily [-- <n> <seed>]`

use submodular_ss::data::{CorpusParams, NewsGenerator};
use submodular_ss::eval::runners::{rouge_of, run_trio, TrioParams};
use submodular_ss::submodular::FeatureBased;

fn main() {
    let mut args = std::env::args().skip(1);
    let n: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(3000);
    let seed: u64 = args.next().and_then(|a| a.parse().ok()).unwrap_or(1);

    let generator = NewsGenerator::new(CorpusParams::default(), seed);
    let day = generator.day(n, 0, seed);
    println!(
        "generated day: {} sentences, {} topics, reference = {} sentences (budget k)",
        day.sentences.len(),
        day.n_topics,
        day.k
    );

    let f = FeatureBased::sqrt(day.feats.clone());
    let results = run_trio(&f, &TrioParams::paper(day.k, seed));

    println!("\n{:<12} {:>10} {:>8} {:>9} {:>9} {:>9} {:>8}",
        "method", "f(S)", "rel", "ROUGE-2", "F1", "time(s)", "memory");
    for m in &results {
        let rouge = rouge_of(&m.set, &day.sentences, &day.reference);
        println!(
            "{:<12} {:>10.3} {:>8.4} {:>9.3} {:>9.3} {:>9.3} {:>8}",
            m.method, m.value, m.rel_utility, rouge.recall, rouge.f1, m.time_s, m.working_set
        );
    }

    let ss = &results[2];
    let sieve = &results[1];
    println!(
        "\npaper shape check: SS rel-utility {:.4} (expect ≈1), sieve {:.4} (expect lower), SS memory {} ≪ n={n}",
        ss.rel_utility, sieve.rel_utility, ss.working_set
    );
}
