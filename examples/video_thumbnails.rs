//! Video thumbnailing — the paper's §4.3 workload on the SumMe-like
//! synthetic substrate: select 15% of frames as a summary with each method,
//! score F1/recall against the voted ground-truth reference and the 15
//! simulated user summaries.
//!
//! Run: `cargo run --release --example video_thumbnails [-- <frames> <seed>]`

use submodular_ss::data::video::{frame_f1_tol, reference_by_score, VideoParams};
use submodular_ss::eval::video_eval::MATCH_TOL;
use submodular_ss::eval::video_eval::run_video;

fn main() {
    let mut args = std::env::args().skip(1);
    let frames: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(2500);
    let seed: u64 = args.next().and_then(|a| a.parse().ok()).unwrap_or(3);

    let rec = run_video("synthetic clip", frames, &VideoParams::default(), seed);
    println!(
        "video: {} frames, {} shots; k = 15% = {} frames",
        frames,
        rec.video.boundaries.len(),
        (frames as f64 * 0.15) as usize
    );

    let reference = reference_by_score(&rec.video, 0.15);
    println!("\nvs ground-truth-score reference (top 15% voted frames):");
    println!("{:<12} {:>8} {:>8} {:>9} {:>9} {:>10}", "method", "F1", "recall", "rel_f", "time(s)", "workset");
    for m in &rec.results {
        let (f1, recall) = frame_f1_tol(&m.set, &reference, MATCH_TOL);
        println!(
            "{:<12} {:>8.3} {:>8.3} {:>9.4} {:>9.3} {:>10}",
            m.method, f1, recall, m.rel_utility, m.time_s, m.working_set
        );
    }

    println!("\nvs individual user summaries (avg over 15 users):");
    for m in &rec.results {
        let mut f1_sum = 0.0;
        let mut rec_sum = 0.0;
        for user in &rec.video.user_selections {
            let (f1, r) = frame_f1_tol(&m.set, user, MATCH_TOL);
            f1_sum += f1;
            rec_sum += r;
        }
        let u = rec.video.user_selections.len() as f64;
        println!("{:<12} avg F1 {:.3}  avg recall {:.3}", m.method, f1_sum / u, rec_sum / u);
    }

    let ss = &rec.results[2];
    println!(
        "\npaper shape check: SS pruned {} -> {} frames ({:.0}%), rel utility {:.4}",
        frames,
        ss.working_set,
        100.0 * ss.working_set as f64 / frames as f64,
        ss.rel_utility
    );
}
