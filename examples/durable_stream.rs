//! Durable streaming through the service: a write-ahead-logged session
//! that survives a crash and recovers **bit-exactly**.
//!
//! The flow: open a durable stream (file-backed WAL + checkpoints under a
//! temp directory), feed it a few batches, snapshot it, run an explicit
//! checkpoint job — then "crash" by tearing the whole service down without
//! closing the stream, and recover the session from the surviving files in
//! a fresh service. The recovered stream's snapshot matches the
//! pre-crash one bit for bit, and it keeps accepting appends with external
//! ids continuing where the crashed session left off.
//!
//! Run: `cargo run --release --example durable_stream [-- <batches> <per_batch> <seed>]`

use submodular_ss::algorithms::SsParams;
use submodular_ss::coordinator::{ServiceConfig, SummarizationService};
use submodular_ss::stream::{
    DurabilityConfig, FileStore, ObjectiveSpec, SnapshotMode, StreamConfig,
};
use submodular_ss::submodular::Concave;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

fn batch(n: usize, d: usize, seed: u64) -> FeatureMatrix {
    let mut rng = Rng::new(seed);
    let mut m = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.row_mut(i)[j] = if rng.bool(0.35) { rng.f32() } else { 0.0 };
        }
    }
    m
}

fn main() {
    let mut args = std::env::args().skip(1);
    let batches: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(6);
    let per_batch: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(400);
    let seed: u64 = args.next().and_then(|a| a.parse().ok()).unwrap_or(1);

    let d = 16;
    let k = 8;
    let dir = std::env::temp_dir().join(format!("ss_durable_stream_{}", std::process::id()));
    let cfg = StreamConfig::new(k)
        .with_ss(SsParams::default().with_seed(seed))
        .with_high_water((2 * per_batch / 3).max(64));
    // auto-checkpoint every 8 WAL records: recovery replays at most that
    // many records on top of the last checkpoint
    let dcfg = DurabilityConfig::default().with_checkpoint_interval(8);

    // --- a durable stream lives its life… ---
    let svc = SummarizationService::start(ServiceConfig::default(), None);
    let id = svc
        .open_stream_durable(
            ObjectiveSpec::Features(Concave::Sqrt),
            d,
            cfg,
            Box::new(FileStore::open(&dir).expect("open durable store")),
            dcfg,
        )
        .expect("open durable stream");
    println!("durable stream {id}: WAL + checkpoints under {}", dir.display());
    for b in 0..batches {
        let rows = batch(per_batch, d, seed.wrapping_add(b as u64 * 101));
        let r = svc.append(id, rows.data()).expect("append");
        println!(
            "batch {b}: +{} rows (ids {}..), {} re-sparsify(s) evicting {}",
            r.appended,
            r.first_ext,
            r.resparsifies,
            r.evicted
        );
    }
    let before = svc
        .submit_snapshot(id, SnapshotMode::Final)
        .expect("submit snapshot")
        .wait()
        .expect("snapshot");
    let ckpt = svc
        .submit_checkpoint(id)
        .expect("submit checkpoint")
        .wait()
        .expect("checkpoint");
    println!(
        "\npre-crash: f(S) = {:.4} over {} live; checkpoint covers seq {} ({} bytes)",
        before.value, before.live, ckpt.seq, ckpt.bytes
    );

    // --- …crashes… ---
    drop(svc); // no close: only the files under `dir` survive
    println!("crash: service torn down without closing the stream");

    // --- …and comes back, bit-identical ---
    let svc = SummarizationService::start(ServiceConfig::default(), None);
    let (rid, report) = svc
        .recover_stream(Box::new(FileStore::open(&dir).expect("reopen store")), dcfg)
        .expect("recover stream");
    println!(
        "recovered as stream {rid}: checkpoint seq {}, {} WAL record(s) replayed, \
         {} torn tail(s) truncated",
        report.checkpoint_seq, report.replayed_records, report.torn_tail_truncations
    );
    let after = svc
        .submit_snapshot(rid, SnapshotMode::Final)
        .expect("submit snapshot")
        .wait()
        .expect("snapshot");
    assert_eq!(after.summary, before.summary, "summaries must match");
    assert_eq!(after.value.to_bits(), before.value.to_bits(), "value must match bit-for-bit");
    println!(
        "post-recovery: f(S) = {:.4} over {} live — identical to the pre-crash snapshot",
        after.value, after.live
    );

    // ids keep flowing from where the crashed session stopped
    let more = batch(50, d, seed.wrapping_add(9999));
    let r = svc.append(rid, more.data()).expect("append after recovery");
    assert_eq!(r.first_ext, batches * per_batch);
    let stats = svc.close(rid).expect("close");
    println!(
        "appended {} more (ids continue at {}); lifetime: {} appended, {} evicted, {} windows",
        r.appended, r.first_ext, stats.appends, stats.evicted, stats.windows
    );
    let _ = std::fs::remove_dir_all(&dir);
}
