//! Rolling news-feed summarization — the workload the streaming subsystem
//! exists for: a long-lived feed (here, synthetic NYT-like days) flows
//! through one `StreamSession` day by day, and the evolving summary is
//! read off with cheap intermediate snapshots instead of re-running the
//! batch pipeline over the whole growing corpus each day (what
//! `news_daily` does per day, and what this example replaces for feeds).
//!
//! Each day: append the day's sentences (the sieve admission grid screens
//! redundant arrivals before they get storage), let the session
//! re-sparsify when its candidate buffer crosses the high-water mark, and
//! print the evolving top-of-feed summary. At the end, a Final snapshot
//! runs the exact `sparsify → lazy greedy` pipeline over the retained
//! core.
//!
//! Run: `cargo run --release --example streaming_news [-- <days> <per_day> <seed>]`

use std::sync::Arc;

use submodular_ss::algorithms::{SieveParams, SsParams};
use submodular_ss::coordinator::Metrics;
use submodular_ss::data::{CorpusParams, NewsGenerator};
use submodular_ss::stream::{ObjectiveSpec, SnapshotMode, StreamConfig, StreamSession};
use submodular_ss::submodular::Concave;
use submodular_ss::util::pool::ThreadPool;

fn main() {
    let mut args = std::env::args().skip(1);
    let days: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(7);
    let per_day: usize = args.next().and_then(|a| a.parse().ok()).unwrap_or(1500);
    let seed: u64 = args.next().and_then(|a| a.parse().ok()).unwrap_or(1);

    let corpus = CorpusParams::default();
    let d = corpus.d;
    let k = 10usize;
    let generator = NewsGenerator::new(corpus, seed);

    let cfg = StreamConfig::new(k)
        .with_ss(SsParams::default().with_seed(seed))
        .with_high_water(per_day)
        .with_admission(SieveParams::paper_default())
        .with_reserve(days * per_day);
    let mut session = StreamSession::new(
        ObjectiveSpec::Features(Concave::Sqrt),
        d,
        cfg,
        Arc::new(ThreadPool::default_for_host()),
        Arc::new(Metrics::new()),
    )
    .expect("open stream session");

    println!(
        "streaming {days} days × ~{per_day} sentences through one session \
         (k = {k}, sieve admission on, high-water = {per_day})\n"
    );
    let mut first_ext_of_day = Vec::with_capacity(days + 1);
    let mut sentences_by_ext: Vec<String> = Vec::new();
    for day in 0..days {
        let news = generator.day(per_day, 0, seed.wrapping_add(day as u64 * 7919));
        first_ext_of_day.push(session.stats().assigned);
        let words = &generator.vocab().words;
        for s in &news.sentences {
            // keep a printable form per external id (ids are assigned in
            // arrival order, admitted or not)
            sentences_by_ext.push(
                s.iter()
                    .take(8)
                    .map(|&t| words[t as usize].as_str())
                    .collect::<Vec<_>>()
                    .join(" "),
            );
        }
        let r = session.append(news.feats.data()).expect("append day");
        let snap = session
            .snapshot_summary(SnapshotMode::Intermediate)
            .expect("intermediate snapshot");
        println!(
            "day {day:>2}: +{} sentences ({} admitted), {} re-sparsify(s) evicting {}, \
             live = {} (retained {} + buffered {}), f(S) = {:.3}",
            r.appended,
            r.admitted,
            r.resparsifies,
            r.evicted,
            snap.live,
            snap.retained,
            snap.buffered,
            snap.value
        );
        for (rank, &ext) in snap.summary.iter().take(3).enumerate() {
            let from_day = first_ext_of_day.iter().rposition(|&f| f <= ext).unwrap_or(0);
            println!(
                "        #{rank} id {ext} (day {from_day}): \"{} …\"",
                sentences_by_ext[ext]
            );
        }
    }

    let fin = session.snapshot_summary(SnapshotMode::Final).expect("final snapshot");
    let (id_base, id_residue) = (session.remap().base(), session.remap().map_residue());
    let stats = session.close();
    println!(
        "\nfinal (exact sparsify → lazy greedy on the retained core): f(S) = {:.3}",
        fin.value
    );
    for (rank, &ext) in fin.summary.iter().enumerate() {
        println!("  #{rank}: id {ext} \"{} …\"", sentences_by_ext[ext]);
    }
    println!(
        "\nlifetime: {} appended, {} admitted by the sieve, {} evicted across {} windows \
         ({} SS rounds); retained core ended at {} of {} seen \
         (filter peak-resident {})",
        stats.appends,
        stats.admitted,
        stats.evicted,
        stats.windows,
        stats.ss_rounds,
        stats.live,
        stats.assigned,
        stats.filter_peak_resident
    );
    println!(
        "id map: {} ids behind the compacted base, {} entries resident \
         (bounded by the live window, not the stream length)",
        id_base, id_residue
    );
}
