//! Distributed composable-coreset flavor (paper §1.2, Mirrokni &
//! Zadimoghaddam [21]): partition the ground set across m "machines", run
//! SS per partition (in parallel on the worker pool), union the reduced
//! sets, and run lazy greedy on the union. The paper notes SS composes with
//! distributed greedy by replacing the per-machine greedy with SS — this
//! example demonstrates exactly that composition.
//!
//! Run: `cargo run --release --example distributed_coreset`

use std::sync::Arc;

use submodular_ss::algorithms::{lazy_greedy, sparsify_candidates, CpuBackend, SsParams};
use submodular_ss::data::{CorpusParams, NewsGenerator};
use submodular_ss::submodular::FeatureBased;
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::stats::Timer;

fn main() {
    let (n, machines, seed) = (6000usize, 4usize, 17u64);
    let generator = NewsGenerator::new(CorpusParams::default(), seed);
    let day = generator.day(n, 0, seed);
    let k = day.k;
    let f = Arc::new(FeatureBased::sqrt(day.feats.clone()));

    // central reference
    let all: Vec<usize> = (0..n).collect();
    let t = Timer::new();
    let central = lazy_greedy(f.as_ref(), &all, k);
    let central_s = t.elapsed_s();
    println!("central lazy greedy:  f = {:.3}  ({central_s:.3}s)", central.value);

    // random partition across machines
    let mut rng = Rng::new(seed);
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let parts: Vec<Vec<usize>> = (0..machines)
        .map(|m| {
            let mut p: Vec<usize> =
                perm.iter().copied().skip(m).step_by(machines).collect();
            p.sort_unstable();
            p
        })
        .collect();

    // per-machine SS in parallel (each machine sees only its partition)
    let pool = ThreadPool::new(machines, machines * 2);
    let t = Timer::new();
    let f2 = Arc::clone(&f);
    let reduced: Vec<Vec<usize>> = pool.parallel_map(parts, 1, move |part| {
        let backend = CpuBackend::new(f2.as_ref());
        sparsify_candidates(&backend, &part, &SsParams::default().with_seed(99)).kept
    });
    let union: Vec<usize> = {
        let mut u: Vec<usize> = reduced.iter().flatten().copied().collect();
        u.sort_unstable();
        u.dedup();
        u
    };
    let combine = lazy_greedy(f.as_ref(), &union, k);
    let dist_s = t.elapsed_s();

    println!(
        "distributed SS ({machines} machines): coreset {} -> union {} -> f = {:.3}  ({dist_s:.3}s)",
        reduced.iter().map(|r| r.len()).sum::<usize>(),
        union.len(),
        combine.value
    );
    println!("relative utility vs central: {:.4}", combine.value / central.value);
    assert!(combine.value / central.value > 0.9, "composable-coreset quality floor");
    println!("distributed_coreset OK");
}
