//! Distributed composable-coreset flavor (paper §1.2, Mirrokni &
//! Zadimoghaddam [21]): partition the ground set across m "machines", run
//! SS per partition, union the reduced sets, and finish centrally.
//!
//! Two renditions of the same composition, printed side by side:
//!
//! 1. **in-process** (the original demo, kept as the quality reference):
//!    partitions pruned on a thread pool, union + lazy greedy inline;
//! 2. **cluster**: the same ground set driven through the real
//!    [`ClusterCoordinator`] / [`WorkerRuntime`] pair over the loopback
//!    transport — framed wire protocol, worker-embedded services,
//!    fan-out, survivor-core merge — i.e. what a multi-process
//!    deployment runs, minus the sockets.
//!
//! Run: `cargo run --release --example distributed_coreset`

use std::sync::Arc;

use submodular_ss::algorithms::{lazy_greedy, sparsify_candidates, CpuBackend, SsParams};
use submodular_ss::cluster::{ClusterConfig, ClusterCoordinator, WorkerConfig, WorkerRuntime};
use submodular_ss::data::{CorpusParams, NewsGenerator};
use submodular_ss::net::{loopback_pair, Transport};
use submodular_ss::submodular::{Concave, FeatureBased, ObjectiveSpec};
use submodular_ss::util::pool::ThreadPool;
use submodular_ss::util::rng::Rng;
use submodular_ss::util::stats::Timer;

fn main() {
    let (n, machines, seed) = (6000usize, 4usize, 17u64);
    let generator = NewsGenerator::new(CorpusParams::default(), seed);
    let day = generator.day(n, 0, seed);
    let k = day.k;
    let f = Arc::new(FeatureBased::sqrt(day.feats.clone()));

    // central reference
    let all: Vec<usize> = (0..n).collect();
    let t = Timer::new();
    let central = lazy_greedy(f.as_ref(), &all, k);
    let central_s = t.elapsed_s();
    println!("central lazy greedy:  f = {:.3}  ({central_s:.3}s)", central.value);

    // ---- rendition 1: in-process composition (the quality reference) ----
    let mut rng = Rng::new(seed);
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let parts: Vec<Vec<usize>> = (0..machines)
        .map(|m| {
            let mut p: Vec<usize> =
                perm.iter().copied().skip(m).step_by(machines).collect();
            p.sort_unstable();
            p
        })
        .collect();

    // per-machine SS in parallel (each machine sees only its partition)
    let pool = ThreadPool::new(machines, machines * 2);
    let t = Timer::new();
    let f2 = Arc::clone(&f);
    let reduced: Vec<Vec<usize>> = pool.parallel_map(parts, 1, move |part| {
        let backend = CpuBackend::new(f2.as_ref());
        sparsify_candidates(&backend, &part, &SsParams::default().with_seed(99)).kept
    });
    let union: Vec<usize> = {
        let mut u: Vec<usize> = reduced.iter().flatten().copied().collect();
        u.sort_unstable();
        u.dedup();
        u
    };
    let combine = lazy_greedy(f.as_ref(), &union, k);
    let dist_s = t.elapsed_s();

    println!(
        "in-process SS ({machines} machines): coreset {} -> union {} -> f = {:.3}  ({dist_s:.3}s)",
        reduced.iter().map(|r| r.len()).sum::<usize>(),
        union.len(),
        combine.value
    );
    println!("relative utility vs central: {:.4}", combine.value / central.value);
    assert!(combine.value / central.value > 0.9, "composable-coreset quality floor");

    // ---- rendition 2: the real coordinator/worker pair over loopback ----
    // each "machine" is a WorkerRuntime serving its embedded service on
    // one end of an in-memory duplex pipe; the coordinator fans logical
    // shards out over the framed wire protocol and merges the cores
    let mut transports: Vec<Box<dyn Transport>> = Vec::new();
    let mut worker_threads = Vec::new();
    for w in 0..machines {
        let (coord_end, worker_end, _kill) = loopback_pair();
        transports.push(Box::new(coord_end));
        worker_threads.push(std::thread::spawn(move || {
            WorkerRuntime::new(WorkerConfig {
                worker_id: w as u64,
                ..WorkerConfig::default()
            })
            .serve(Box::new(worker_end))
        }));
    }
    let cfg = ClusterConfig { shards: machines as u32, seed, ..ClusterConfig::default() };
    let coordinator = ClusterCoordinator::connect(transports, cfg).expect("handshake");
    let t = Timer::new();
    let resp = coordinator
        .summarize(
            ObjectiveSpec::Features(Concave::Sqrt),
            &day.feats,
            k,
            &SsParams::default().with_seed(99),
        )
        .expect("cluster summarize");
    let cluster_s = t.elapsed_s();
    println!(
        "cluster SS ({machines} workers): union {} -> final {} -> f = {:.3}  ({cluster_s:.3}s, {} shard rounds)",
        resp.union, resp.final_reduced, resp.value, resp.shard_rounds
    );
    println!("relative utility vs central: {:.4}", resp.value / central.value);
    assert!(resp.value / central.value > 0.9, "cluster composition quality floor");

    drop(coordinator); // sends Shutdown, closes connections
    for h in worker_threads {
        let report = h.join().expect("worker thread").expect("worker serve");
        assert!(report.saw_shutdown, "workers end via explicit shutdown");
    }
    println!("distributed_coreset OK");
}
