//! Quickstart: the paper's pipeline in ~40 lines.
//!
//! Build a redundant ground set, run Algorithm 1 (submodular
//! sparsification) to prune it, and lazy-greedy-maximize on the reduced set;
//! compare against lazy greedy on the full set.
//!
//! Run: `cargo run --release --example quickstart`

use submodular_ss::algorithms::{lazy_greedy, sparsify, CpuBackend, SsParams};
use submodular_ss::submodular::{FeatureBased, SubmodularFn};
use submodular_ss::util::rng::Rng;
use submodular_ss::util::vecmath::FeatureMatrix;

fn main() {
    // A ground set with redundancy: 2000 items around 15 cluster centers.
    let (n, d, clusters, k) = (2000usize, 64usize, 15usize, 20usize);
    let mut rng = Rng::new(42);
    let centers: Vec<Vec<f32>> = (0..clusters)
        .map(|_| (0..d).map(|_| if rng.bool(0.3) { rng.f32() * 2.0 } else { 0.0 }).collect())
        .collect();
    let mut feats = FeatureMatrix::zeros(n, d);
    for i in 0..n {
        let c = &centers[i % clusters];
        for j in 0..d {
            feats.row_mut(i)[j] = (c[j] + 0.05 * rng.f32()).max(0.0);
        }
    }

    // The paper's objective: f(S) = sum_j sqrt(c_j(S)).
    let f = FeatureBased::sqrt(feats);
    let all: Vec<usize> = (0..f.n()).collect();

    // Baseline: lazy greedy on the full ground set.
    let full = lazy_greedy(&f, &all, k);
    println!("lazy greedy on |V| = {n}: f(S) = {:.3} ({} oracle calls, {:.3}s)",
        full.value, full.oracle_calls, full.wall_s);

    // Submodular sparsification (Algorithm 1), then greedy on V'.
    let backend = CpuBackend::new(&f);
    let ss = sparsify(&backend, &SsParams::default().with_seed(7));
    println!(
        "SS pruned {n} -> |V'| = {} in {} rounds ({} divergence evals, {:.3}s)",
        ss.kept.len(), ss.rounds, ss.divergence_evals, ss.wall_s
    );

    let reduced = lazy_greedy(&f, &ss.kept, k);
    println!("lazy greedy on V': f(S') = {:.3} ({} oracle calls, {:.3}s)",
        reduced.value, reduced.oracle_calls, reduced.wall_s);
    println!("relative utility f(S')/f(S) = {:.4}", reduced.value / full.value);

    assert!(reduced.value / full.value > 0.9, "SS should preserve ≥90% utility here");
    println!("quickstart OK");
}
