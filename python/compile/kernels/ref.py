"""Pure-jnp oracle for the Layer-1 Pallas kernels.

All three hot-loop computations of the SS pipeline are defined here in plain
jax.numpy, with no Pallas, no tiling and no padding tricks. The Pallas
kernels in this package must agree with these to float32 tolerance; pytest +
hypothesis enforce that at build time (python/tests/test_kernel.py).

Objective: the paper's feature-based submodular function

    f(S) = sum_j g(c_j(S)),   c_j(S) = sum_{v in S} w_{vj},  g concave.

The paper uses g = sqrt; log1p is provided as an extension (the analysis only
needs concavity + normalization g(0) = 0).
"""

import jax.numpy as jnp

# Concave scalarizers g. Each maps non-negative modular mass to utility.
CONCAVE = {
    "sqrt": jnp.sqrt,
    "log1p": jnp.log1p,
}


def feature_utility(feats, g="sqrt"):
    """f(S) for a stacked feature matrix ``feats`` of shape (|S|, D)."""
    return jnp.sum(CONCAVE[g](jnp.sum(feats, axis=0)))


def marginal_gains_ref(cov, v_feat, g="sqrt"):
    """f(v|S) for every row v of ``v_feat`` given coverage ``cov = c(S)``.

    cov: (D,) non-negative accumulated feature mass of the current solution.
    v_feat: (B, D) candidate features.
    returns: (B,) gains  sum_d [ g(cov_d + v_d) - g(cov_d) ].
    """
    gfun = CONCAVE[g]
    return jnp.sum(gfun(cov[None, :] + v_feat) - gfun(cov)[None, :], axis=-1)


def singleton_complement_ref(total, v_feat, g="sqrt"):
    """f(v | V \\ v) for every row v, given ``total = c(V)``.

    By definition f(v|V\\v) = f(V) - f(V\\v) = sum_d [ g(t_d) - g(t_d - v_d) ].
    The subtraction is clamped at 0 to absorb float round-off when v's mass
    nearly equals the total in some dimension.
    """
    gfun = CONCAVE[g]
    rem = jnp.maximum(total[None, :] - v_feat, 0.0)
    return jnp.sum(gfun(total)[None, :] - gfun(rem), axis=-1)


def edge_weights_ref(u_feat, u_sing, v_feat, g="sqrt"):
    """Submodularity-graph divergences w_{U,v} = min_u [ f(v|u) - f(u|V\\u) ].

    u_feat: (P, D) probe features, u_sing: (P,) precomputed f(u|V\\u),
    v_feat: (B, D) remaining items. Returns (B,) divergences.

    f(v|u) = sum_d [ g(u_d + v_d) - g(u_d) ]  (marginal gain of v on {u}).
    """
    gfun = CONCAVE[g]
    # (B, P, D) broadcast-reduce; the Pallas kernel tiles this.
    pair = gfun(v_feat[:, None, :] + u_feat[None, :, :]) - gfun(u_feat)[None, :, :]
    gains = jnp.sum(pair, axis=-1)  # (B, P) = f(v|u)
    w = gains - u_sing[None, :]  # (B, P) = w_{uv}
    return jnp.min(w, axis=1)
