"""Layer-1 Pallas kernel: batched marginal gains f(v|S).

Each greedy step needs f(v|S) = sum_d [ g(c_d + v_d) - g(c_d) ] for every
candidate v, where c = c(S) is the solution's accumulated feature mass. This
is the per-step hot loop of the (lazy) greedy algorithm when run in
"accelerated" mode through the PJRT runtime.

The grid walks (BLOCK_B, D) item blocks; the coverage vector (D,) is
VMEM-resident across the grid (constant index_map). The per-block footprint
is BLOCK_B*D + D + BLOCK_B f32 words — trivially VMEM-fit; the kernel is
bandwidth-bound on the item stream, which is exactly the structure a TPU
wants (stream HBM → VMEM blocks, VPU element-wise + lane reduction).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import CONCAVE
from .edge_weight import B, D, BLOCK_B  # shared tile geometry


def _marginal_gain_kernel(c_ref, v_ref, o_ref, *, g):
    gfun = CONCAVE[g]
    c = c_ref[...]  # (D,) coverage c(S), resident
    v = v_ref[...]  # (BLOCK_B, D) candidates
    o_ref[...] = jnp.sum(gfun(c[None, :] + v) - gfun(c)[None, :], axis=-1)


@functools.partial(jax.jit, static_argnames=("g", "block_b"))
def marginal_gains(cov, v_feat, g="sqrt", block_b=None):
    """f(v|S) for every row of ``v_feat`` (B, D); ``cov`` is c(S) of shape (D,).

    B must be a multiple of ``block_b``; padded item rows produce garbage the
    caller discards (zero rows produce gain 0, which is also safe for argmax
    because real gains are >= 0 and ties resolve to real indices first in the
    Rust runtime).
    """
    b, d = v_feat.shape
    if block_b is None:  # largest default block that tiles B exactly
        block_b = BLOCK_B if b % BLOCK_B == 0 else b
    assert b % block_b == 0, f"B={b} must be a multiple of block_b={block_b}"
    return pl.pallas_call(
        functools.partial(_marginal_gain_kernel, g=g),
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), v_feat.dtype),
        interpret=True,
    )(cov, v_feat)
