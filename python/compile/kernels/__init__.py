"""Layer-1 Pallas kernels for the SS pipeline hot loops.

Three kernels cover everything the Rust coordinator dispatches to PJRT:

* edge_weight    -- w_{U,v} divergences (Algorithm 1, line 9)
* marginal_gain  -- f(v|S) batches (greedy steps)
* singleton      -- f(v|V\\v) precompute (used in every edge weight)

`ref` holds the pure-jnp oracles the kernels are tested against.
"""

from . import ref  # noqa: F401
from .edge_weight import edge_weights, P, B, D, BLOCK_B  # noqa: F401
from .marginal_gain import marginal_gains  # noqa: F401
from .singleton import singleton_complement  # noqa: F401
