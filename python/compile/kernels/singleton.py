"""Layer-1 Pallas kernel: singleton-complement gains f(v|V\\v).

SS precomputes f(u|V\\u) once, in linear time, before the pruning rounds
(Algorithm 1 line 9 uses it inside every edge weight). For the feature-based
objective:

    f(v|V\\v) = f(V) - f(V\\v) = sum_d [ g(t_d) - g(t_d - v_d) ],

with t = c(V) the total feature mass. Same grid structure as the marginal
gain kernel: (BLOCK_B, D) item blocks streamed against a VMEM-resident (D,)
total vector. The subtraction is clamped at zero: in exact arithmetic
t_d - v_d >= 0, but the Rust runtime accumulates t in f32 so round-off can
push it a ULP under zero which would NaN under sqrt.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import CONCAVE
from .edge_weight import B, D, BLOCK_B  # shared tile geometry


def _singleton_kernel(t_ref, v_ref, o_ref, *, g):
    gfun = CONCAVE[g]
    t = t_ref[...]  # (D,) total mass c(V), resident
    v = v_ref[...]  # (BLOCK_B, D)
    rem = jnp.maximum(t[None, :] - v, 0.0)
    o_ref[...] = jnp.sum(gfun(t)[None, :] - gfun(rem), axis=-1)


@functools.partial(jax.jit, static_argnames=("g", "block_b"))
def singleton_complement(total, v_feat, g="sqrt", block_b=None):
    """f(v|V\\v) for every row of ``v_feat`` (B, D); ``total`` = c(V), (D,)."""
    b, d = v_feat.shape
    if block_b is None:  # largest default block that tiles B exactly
        block_b = BLOCK_B if b % BLOCK_B == 0 else b
    assert b % block_b == 0, f"B={b} must be a multiple of block_b={block_b}"
    return pl.pallas_call(
        functools.partial(_singleton_kernel, g=g),
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), v_feat.dtype),
        interpret=True,
    )(total, v_feat)
