"""Layer-1 Pallas kernel: submodularity-graph divergences w_{U,v}.

This is the hot spot of Algorithm 1 (Submodular Sparsification): each round
computes, for every remaining item v, the divergence

    w_{U,v} = min_{u in U} [ f(v|u) - f(u|V\\u) ]

against the freshly sampled probe set U. For the paper's feature-based
objective f(S) = sum_d g(c_d(S)) the pairwise gain is

    f(v|u) = sum_d [ g(u_d + v_d) - g(u_d) ],

so the whole round is a (B x P x D) broadcast-reduce followed by a min over
the probe axis — structurally a "soft distance matrix" kernel.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks item blocks
of shape (BLOCK_B, D); the probe tile (P, D) and singleton vector (P,) use a
constant index_map so Pallas keeps them resident in VMEM across the whole
grid — the analogue of staging into CUDA shared memory. The (BLOCK_B, P, D)
intermediate lives in registers/VMEM of one grid step; the min over P never
leaves the block. There is no matmul, so the kernel is VPU-bound; BLOCK_B is
chosen so the block footprint stays ~1 MB (far under the ~16 MB VMEM budget):
    P*D + BLOCK_B*D + BLOCK_B*P + BLOCK_B  f32 words.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated through this path and real-TPU perf is
estimated analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import CONCAVE

# Default tile geometry; aot.py compiles artifacts at these shapes and the
# Rust runtime pads up to them. Chosen for VMEM fit + lane alignment (128).
P = 32  # probes per tile
B = 256  # items per call
D = 256  # feature dims (datasets are feature-hashed to D)
BLOCK_B = 128  # items per grid step


def _edge_weight_kernel(u_ref, s_ref, v_ref, o_ref, *, g):
    """One grid step: divergences for a (BLOCK_B, D) item block."""
    gfun = CONCAVE[g]
    u = u_ref[...]  # (P, D) probe tile, VMEM-resident across grid
    s = s_ref[...]  # (P,)  f(u|V\u) per probe
    v = v_ref[...]  # (BLOCK_B, D) item block for this step
    # (BLOCK_B, P, D) broadcast; reduce D -> pairwise gains f(v|u).
    pair = gfun(v[:, None, :] + u[None, :, :]) - gfun(u)[None, :, :]
    gains = jnp.sum(pair, axis=-1)  # (BLOCK_B, P)
    w = gains - s[None, :]  # w_{uv} = f(v|u) - f(u|V\u)
    o_ref[...] = jnp.min(w, axis=1)  # divergence w_{U,v}


@functools.partial(jax.jit, static_argnames=("g", "block_b"))
def edge_weights(u_feat, u_sing, v_feat, g="sqrt", block_b=None):
    """Divergences w_{U,v} for a padded item batch.

    u_feat: (P, D), u_sing: (P,), v_feat: (B, D) with B % block_b == 0.
    Padding contract (the Rust runtime relies on this):
      * pad probe rows with zeros and their u_sing with -1e30 → the padded
        lane's weight is ≈ +1e30 and never wins the min;
      * pad feature dims with zeros → g(0+x) - g(0) contributes g(x) for
        g=sqrt only when x>0, so items must also be zero-padded there (they
        are: both sides share the same hashed feature space);
      * pad item rows arbitrarily → caller discards those outputs.
    """
    b, d = v_feat.shape
    p = u_feat.shape[0]
    if block_b is None:  # largest default block that tiles B exactly
        block_b = BLOCK_B if b % BLOCK_B == 0 else b
    assert b % block_b == 0, f"B={b} must be a multiple of block_b={block_b}"
    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_edge_weight_kernel, g=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, d), lambda i: (0, 0)),  # probes: resident
            pl.BlockSpec((p,), lambda i: (0,)),  # singletons: resident
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),  # item block
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), v_feat.dtype),
        interpret=True,
    )(u_feat, u_sing, v_feat)
