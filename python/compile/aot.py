"""AOT lowering: JAX/Pallas graphs -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--p 32 --b 256 --d 256]

Emits one ``<name>.hlo.txt`` per artifact plus ``manifest.json`` describing
shapes, so the Rust runtime can validate its padding contract at load time.
Runs a numeric self-check of every graph against the pure-jnp oracle before
writing anything.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def self_check(p, b, d, rtol=1e-5, atol=1e-5):
    """Run every graph on random data and compare to the oracle."""
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.uniform(0, 3, (p, d)), jnp.float32)
    v = jnp.asarray(rng.uniform(0, 3, (b, d)), jnp.float32)
    s = jnp.asarray(rng.uniform(0, 1, (p,)), jnp.float32)
    cov = jnp.asarray(rng.uniform(0, 5, (d,)), jnp.float32)
    total = jnp.sum(v, axis=0) + cov  # ensure total >= any row
    mask = jnp.asarray(rng.integers(0, 2, (b,)), jnp.float32)

    checks = {
        "edge_weights": (model.edge_weights_graph(u, s, v)[0], ref.edge_weights_ref(u, s, v)),
        "marginal_gains": (model.marginal_gains_graph(cov, v)[0], ref.marginal_gains_ref(cov, v)),
        "singleton": (model.singleton_graph(total, v)[0], ref.singleton_complement_ref(total, v)),
        "ss_round": (model.ss_round_graph(u, s, v)[0], ref.edge_weights_ref(u, s, v)),
        "utility": (
            model.utility_graph(v, mask)[0],
            jnp.sum(jnp.sqrt(jnp.sum(v * mask[:, None], axis=0)), keepdims=True),
        ),
    }
    for name, (got, want) in checks.items():
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=atol)
        print(f"  self-check {name}: OK ({np.asarray(got).shape})")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--p", type=int, default=32, help="probes per tile")
    ap.add_argument("--b", type=int, default=256, help="items per tile")
    ap.add_argument("--d", type=int, default=256, help="feature dims")
    ap.add_argument("--skip-check", action="store_true")
    args = ap.parse_args()

    if not args.skip_check:
        print("running numeric self-checks (pallas interpret vs jnp oracle)...")
        self_check(args.p, args.b, args.d)

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"p": args.p, "b": args.b, "d": args.d, "dtype": "f32", "artifacts": {}}
    for name, fn, example in model.artifact_specs(args.p, args.b, args.d):
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [list(a.shape) for a in example],
            "chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
