"""Layer-2 JAX compute graphs for the SS pipeline.

Each public function here is a jit-able graph that the AOT step
(``python -m compile.aot``) lowers to HLO text for the Rust runtime. The
graphs call the Layer-1 Pallas kernels, so kernel and surrounding glue lower
into one HLO module per artifact.

Artifacts (all float32, shapes fixed at AOT time; Rust pads up):

* ``edge_weights``      (P,D),(P,),(B,D) -> (B,)   divergences w_{U,v}
* ``marginal_gains``    (D,),(B,D)       -> (B,)   f(v|S) batch
* ``singleton``         (D,),(B,D)       -> (B,)   f(v|V\\v) batch
* ``ss_round``          (P,D),(P,),(B,D) -> (B,),(1,)  fused round: divergences
                         plus the block-min (used by the coordinator to cheap-
                         check degenerate rounds without a second pass)
* ``utility``           (B,D),(B,)       -> (1,)   masked f(S) evaluation

The fused ``ss_round`` exists for dispatch amortization (DESIGN.md §Perf):
one PJRT call per item tile per round instead of two.
"""

import jax
import jax.numpy as jnp

from .kernels import edge_weights, marginal_gains, singleton_complement
from .kernels.ref import CONCAVE


def edge_weights_graph(u_feat, u_sing, v_feat):
    """Divergence graph — thin wrapper so the artifact is a 1-tuple."""
    return (edge_weights(u_feat, u_sing, v_feat),)


def marginal_gains_graph(cov, v_feat):
    return (marginal_gains(cov, v_feat),)


def singleton_graph(total, v_feat):
    return (singleton_complement(total, v_feat),)


def ss_round_graph(u_feat, u_sing, v_feat):
    """Fused SS round step: divergences + their block minimum."""
    w = edge_weights(u_feat, u_sing, v_feat)
    return (w, jnp.min(w, keepdims=True))


def utility_graph(v_feat, mask, g="sqrt"):
    """Masked objective evaluation f({v : mask_v = 1}).

    Used by the service to score final summaries on-device. mask is f32
    (0.0/1.0) so the whole graph stays in one dtype.
    """
    cov = jnp.sum(v_feat * mask[:, None], axis=0)
    return (jnp.sum(CONCAVE[g](cov), keepdims=True),)


# (name, fn, example-arg builder) — consumed by aot.py and tests.
def artifact_specs(p, b, d):
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return [
        ("edge_weights", edge_weights_graph, (s((p, d), f32), s((p,), f32), s((b, d), f32))),
        ("marginal_gains", marginal_gains_graph, (s((d,), f32), s((b, d), f32))),
        ("singleton", singleton_graph, (s((d,), f32), s((b, d), f32))),
        ("ss_round", ss_round_graph, (s((p, d), f32), s((p,), f32), s((b, d), f32))),
        ("utility", utility_graph, (s((b, d), f32), s((b,), f32))),
    ]
