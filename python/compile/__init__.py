"""Build-time compile path: JAX/Pallas authoring + AOT lowering to HLO text.

Never imported at runtime; `make artifacts` runs `python -m compile.aot`
once and the Rust binary is self-contained afterwards.
"""
