"""Kernel-vs-oracle correctness: the CORE numeric signal of the build.

hypothesis sweeps tile geometries and value ranges; every Pallas kernel must
match the pure-jnp oracle in float32. Shapes are kept small (interpret mode
is numpy-speed) but cover: single-block, multi-block, non-square, P=1, and
the padding contracts the Rust runtime relies on.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import edge_weights, marginal_gains, singleton_complement
from compile.kernels import ref

RTOL, ATOL = 1e-4, 1e-4


def _rand(rng, shape, lo=0.0, hi=4.0):
    return jnp.asarray(rng.uniform(lo, hi, shape), jnp.float32)


# block_b must divide b; sample (blocks, block_b) then derive b.
geoms = st.tuples(
    st.integers(1, 3),  # grid blocks
    st.sampled_from([4, 8, 16]),  # block_b
    st.integers(1, 12),  # P
    st.sampled_from([3, 8, 32, 100]),  # D
    st.integers(0, 2**32 - 1),  # seed
)


@settings(max_examples=25, deadline=None)
@given(geoms)
def test_edge_weights_matches_ref(geom):
    blocks, bb, p, d, seed = geom
    rng = np.random.default_rng(seed)
    u, v = _rand(rng, (p, d)), _rand(rng, (blocks * bb, d))
    s = _rand(rng, (p,), 0.0, 1.0)
    got = edge_weights(u, s, v, block_b=bb)
    want = ref.edge_weights_ref(u, s, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(geoms)
def test_marginal_gains_matches_ref(geom):
    blocks, bb, _, d, seed = geom
    rng = np.random.default_rng(seed)
    cov, v = _rand(rng, (d,), 0.0, 10.0), _rand(rng, (blocks * bb, d))
    got = marginal_gains(cov, v, block_b=bb)
    want = ref.marginal_gains_ref(cov, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(geoms)
def test_singleton_matches_ref(geom):
    blocks, bb, _, d, seed = geom
    rng = np.random.default_rng(seed)
    v = _rand(rng, (blocks * bb, d))
    total = jnp.sum(v, axis=0) + _rand(rng, (d,), 0.0, 1.0)
    got = singleton_complement(total, v, block_b=bb)
    want = ref.singleton_complement_ref(total, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 8), st.integers(1, 8))
def test_probe_padding_is_inert(seed, p_real, p_pad):
    """Padded probe lanes (zero feats, sing = -1e30) never win the min."""
    rng = np.random.default_rng(seed)
    d, b = 16, 8
    u = _rand(rng, (p_real, d))
    s = _rand(rng, (p_real,), 0.0, 1.0)
    v = _rand(rng, (b, d))
    u_pad = jnp.concatenate([u, jnp.zeros((p_pad, d), jnp.float32)])
    s_pad = jnp.concatenate([s, jnp.full((p_pad,), -1e30, jnp.float32)])
    got = edge_weights(u_pad, s_pad, v, block_b=b)
    want = edge_weights(u, s, v, block_b=b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 16))
def test_feature_dim_padding_is_inert(seed, d_pad):
    """Zero-padded feature dims contribute nothing to any kernel output."""
    rng = np.random.default_rng(seed)
    p, d, b = 4, 12, 8
    u, v = _rand(rng, (p, d)), _rand(rng, (b, d))
    s = _rand(rng, (p,), 0.0, 1.0)
    zp, zv = jnp.zeros((p, d_pad)), jnp.zeros((b, d_pad))
    got = edge_weights(
        jnp.concatenate([u, zp], axis=1), s, jnp.concatenate([v, zv], axis=1), block_b=b
    )
    want = edge_weights(u, s, v, block_b=b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


def test_edge_weight_self_edge_nonpositive():
    """w_{uu} = -f(u|V\\u) <= 0 (Proposition 1's A_u argument needs this)."""
    rng = np.random.default_rng(7)
    d = 16
    u = _rand(rng, (1, d))
    # f(u|u) = sum_d [sqrt(2u) - sqrt(u)] — NOT zero under feature overlap;
    # the self-edge claim w_uu <= 0 is about identical elements, i.e. v = u
    # as a *set* element: f(u|u) = 0 by definition of marginal gain on sets.
    # The kernel computes the feature form, so we emulate the set semantics
    # the Rust layer uses: v == u means gain 0, weight = -sing.
    s = jnp.asarray([0.3], jnp.float32)
    w = ref.edge_weights_ref(u, s, jnp.zeros((1, d), jnp.float32))
    assert float(w[0]) == pytest.approx(-0.3, abs=1e-6)


def test_min_over_probes_monotone():
    """Adding probes can only lower divergences (min over a superset)."""
    rng = np.random.default_rng(11)
    d, b = 16, 8
    u1, u2 = _rand(rng, (3, d)), _rand(rng, (5, d))
    s1, s2 = _rand(rng, (3,), 0, 1), _rand(rng, (5,), 0, 1)
    v = _rand(rng, (b, d))
    w_small = edge_weights(u1, s1, v, block_b=b)
    w_big = edge_weights(
        jnp.concatenate([u1, u2]), jnp.concatenate([s1, s2]), v, block_b=b
    )
    assert np.all(np.asarray(w_big) <= np.asarray(w_small) + ATOL)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_log1p_concave_variant(seed):
    """The g='log1p' extension follows the same oracle contract."""
    rng = np.random.default_rng(seed)
    p, d, b = 3, 10, 8
    u, v = _rand(rng, (p, d)), _rand(rng, (b, d))
    s = _rand(rng, (p,), 0, 1)
    got = edge_weights(u, s, v, g="log1p", block_b=b)
    want = ref.edge_weights_ref(u, s, v, g="log1p")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)
