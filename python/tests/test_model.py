"""Layer-2 graph tests: shapes, fusion outputs, artifact spec consistency."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

P, B, D = 8, 16, 32  # small geometry for graph tests


def _data(seed=0):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.uniform(0, 3, (P, D)), jnp.float32)
    v = jnp.asarray(rng.uniform(0, 3, (B, D)), jnp.float32)
    s = jnp.asarray(rng.uniform(0, 1, (P,)), jnp.float32)
    return u, s, v


def test_ss_round_fuses_min():
    u, s, v = _data()
    w, wmin = model.ss_round_graph(u, s, v)
    assert w.shape == (B,) and wmin.shape == (1,)
    np.testing.assert_allclose(float(wmin[0]), float(jnp.min(w)), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(w), np.asarray(ref.edge_weights_ref(u, s, v)), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_utility_graph_matches_masked_oracle(seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.uniform(0, 3, (B, D)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, (B,)), jnp.float32)
    (got,) = model.utility_graph(v, mask)
    rows = np.asarray(v)[np.asarray(mask) > 0.5]
    want = np.sum(np.sqrt(np.sum(rows, axis=0))) if rows.size else 0.0
    np.testing.assert_allclose(float(got[0]), float(want), rtol=1e-5, atol=1e-5)


def test_utility_empty_mask_is_zero():
    """f(empty) = 0 — normalization the paper's bounds require."""
    v = jnp.ones((B, D), jnp.float32)
    (got,) = model.utility_graph(v, jnp.zeros((B,), jnp.float32))
    assert float(got[0]) == 0.0


def test_artifact_specs_shapes():
    specs = model.artifact_specs(4, 8, 16)
    names = [n for n, _, _ in specs]
    assert names == ["edge_weights", "marginal_gains", "singleton", "ss_round", "utility"]
    for name, fn, example in specs:
        out = jax.eval_shape(fn, *example)
        assert isinstance(out, tuple) and len(out) >= 1
        assert out[0].shape[0] == 8 or name == "utility"


def test_all_graphs_lower_to_stablehlo():
    """Every artifact graph must lower (the AOT precondition)."""
    for name, fn, example in model.artifact_specs(4, 8, 16):
        ir = jax.jit(fn).lower(*example).compiler_ir("stablehlo")
        assert "func.func" in str(ir), name
