"""AOT emission tests: HLO text is parseable-looking, manifest is coherent.

These run the same lowering path as `make artifacts` at a small geometry so
they are fast, and additionally validate the real artifacts/ directory when
it exists (post-`make artifacts` in CI order).
"""

import json
import os

import jax

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_emits_entry_computation():
    specs = model.artifact_specs(4, 8, 16)
    for name, fn, example in specs:
        text = aot.to_hlo_text(jax.jit(fn).lower(*example))
        assert "HloModule" in text, name
        assert "ENTRY" in text, name
        # f32 I/O as the runtime expects
        assert "f32[" in text, name


def test_self_check_small_geometry():
    aot.self_check(4, 8, 16)


def test_manifest_matches_artifacts_on_disk():
    if not os.path.exists(os.path.join(ART, "manifest.json")):
        import pytest

        pytest.skip("artifacts/ not built yet (run `make artifacts`)")
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["dtype"] == "f32"
    assert set(manifest["artifacts"]) == {
        "edge_weights",
        "marginal_gains",
        "singleton",
        "ss_round",
        "utility",
    }
    p, b, d = manifest["p"], manifest["b"], manifest["d"]
    assert manifest["artifacts"]["edge_weights"]["inputs"] == [[p, d], [p], [b, d]]
    for meta in manifest["artifacts"].values():
        path = os.path.join(ART, meta["file"])
        assert os.path.exists(path)
        with open(path) as f:
            text = f.read()
        assert len(text) == meta["chars"]
        assert text.startswith("HloModule")
